//! Tile-kernel runtime: loads the AOT-compiled JAX/Pallas artifacts from
//! `artifacts/*.hlo.txt` and executes them for functionally-executed tiles.
//!
//! This is the only place the three layers meet at run time: Python lowered
//! the Layer-2 model (which calls the Layer-1 Pallas kernels) to HLO
//! **text** once (`make artifacts`), and this module executes those
//! artifacts from Rust. Python never runs on the simulation path.
//!
//! Two interchangeable backends:
//!
//! * **`pjrt` feature** — compiles the HLO text with the XLA CPU PJRT
//!   client (the original paper-artifact path). Requires the external
//!   `xla` and `anyhow` crates; offline builds have no registry access,
//!   so the feature is declared dependency-free in `Cargo.toml` and the
//!   crates must be vendored before enabling it. HLO text is the
//!   interchange format: jax >= 0.5 serializes protos with 64-bit
//!   instruction ids that xla_extension 0.5.1 rejects; the text parser
//!   reassigns ids.
//! * **default (native)** — a std-only executor with the same kernel
//!   semantics as the Pallas reference oracles
//!   (`python/compile/kernels/ref.py`). It reads the same
//!   `artifacts/manifest.txt` for shapes and artifact names, so the CLI
//!   smoke test (`dx100 runtime`) and callers behave identically.

/// Default artifact directory relative to the repo root.
pub const ARTIFACT_DIR: &str = "artifacts";

/// Shapes baked into the AOT artifacts (mirrors python/compile/model.py).
#[derive(Clone, Copy, Debug)]
pub struct TileShapes {
    /// Elements per scratchpad tile.
    pub tile: usize,
    /// Elements in the data array.
    pub data_n: usize,
    /// Maximum elements a range expansion may produce.
    pub range_cap: usize,
}

/// Parse the manifest header (`tile=4096 data_n=262144 range_cap=16384`).
/// Unknown keys are ignored; a malformed value for a known key is a hard
/// error (a silently-defaulted shape would surface later as a confusing
/// shape-mismatch at execution time).
fn parse_shapes(header: &str) -> Result<TileShapes, String> {
    let mut shapes = TileShapes {
        tile: 4096,
        data_n: 1 << 18,
        range_cap: 4 * 4096,
    };
    for kv in header.split_whitespace() {
        let mut it = kv.split('=');
        let (key, value) = (it.next(), it.next());
        let slot = match key {
            Some("tile") => &mut shapes.tile,
            Some("data_n") => &mut shapes.data_n,
            Some("range_cap") => &mut shapes.range_cap,
            _ => continue,
        };
        *slot = value
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| format!("manifest header: bad value in `{kv}`"))?;
    }
    Ok(shapes)
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::{parse_shapes, TileShapes, ARTIFACT_DIR};
    use std::fmt;
    use std::path::{Path, PathBuf};

    /// Error from the native tile runtime.
    #[derive(Debug)]
    pub struct RuntimeError(pub String);

    impl fmt::Display for RuntimeError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    impl std::error::Error for RuntimeError {}

    pub type Result<T> = std::result::Result<T, RuntimeError>;

    fn err<T>(msg: impl Into<String>) -> Result<T> {
        Err(RuntimeError(msg.into()))
    }

    /// Native tile executor: same manifest, same shapes, reference kernel
    /// semantics in pure Rust.
    pub struct TileRuntime {
        names: Vec<String>,
        /// Shapes baked into the loaded artifacts.
        pub shapes: TileShapes,
    }

    impl TileRuntime {
        /// Load the manifest in `dir` (shape header + artifact names).
        pub fn load(dir: &Path) -> Result<Self> {
            let manifest = std::fs::read_to_string(dir.join("manifest.txt")).map_err(|e| {
                RuntimeError(format!(
                    "missing manifest in {dir:?}; run `make artifacts`: {e}"
                ))
            })?;
            let shapes =
                parse_shapes(manifest.lines().next().unwrap_or_default()).map_err(RuntimeError)?;
            let mut names: Vec<String> = manifest
                .lines()
                .skip(1)
                .filter_map(|l| l.split_whitespace().next())
                .map(str::to_string)
                .collect();
            names.sort();
            Ok(TileRuntime { names, shapes })
        }

        /// Load from the conventional `artifacts/` directory next to the
        /// current working directory (or its parents).
        pub fn load_default() -> Result<Self> {
            Self::load(&find_artifacts()?)
        }

        /// Human-readable backend description.
        pub fn platform(&self) -> String {
            "native (enable the `pjrt` feature for XLA execution)".to_string()
        }

        /// Whether artifact `name` is in the manifest.
        pub fn has(&self, name: &str) -> bool {
            self.names.iter().any(|n| n == name)
        }

        /// Sorted artifact names from the manifest.
        pub fn names(&self) -> Vec<&str> {
            self.names.iter().map(String::as_str).collect()
        }

        /// `out[i] = data[idx[i]]`.
        pub fn gather_f32(&self, data: &[f32], idx: &[i32]) -> Result<Vec<f32>> {
            self.check_shapes(data.len(), idx.len())?;
            idx.iter()
                .map(|&i| match data.get(i as usize) {
                    Some(&v) => Ok(v),
                    None => err(format!("gather index {i} out of bounds")),
                })
                .collect()
        }

        /// `data[idx[i]] += vals[i]` (duplicates accumulate).
        pub fn scatter_add_f32(&self, data: &[f32], idx: &[i32], vals: &[f32]) -> Result<Vec<f32>> {
            self.check_shapes(data.len(), idx.len())?;
            let mut out = data.to_vec();
            for (&i, &v) in idx.iter().zip(vals) {
                match out.get_mut(i as usize) {
                    Some(slot) => *slot += v,
                    None => return err(format!("scatter index {i} out of bounds")),
                }
            }
            Ok(out)
        }

        /// `data[idx[i]] = vals[i]` (last write wins).
        pub fn scatter_set_f32(&self, data: &[f32], idx: &[i32], vals: &[f32]) -> Result<Vec<f32>> {
            self.check_shapes(data.len(), idx.len())?;
            let mut out = data.to_vec();
            for (&i, &v) in idx.iter().zip(vals) {
                match out.get_mut(i as usize) {
                    Some(slot) => *slot = v,
                    None => return err(format!("scatter index {i} out of bounds")),
                }
            }
            Ok(out)
        }

        /// One SpMV tile: `y[row[k]] += vals[k] * x[col[k]]`.
        pub fn spmv_tile_f32(
            &self,
            vals: &[f32],
            col: &[i32],
            row: &[i32],
            x: &[f32],
            y: &[f32],
        ) -> Result<Vec<f32>> {
            let mut out = y.to_vec();
            for k in 0..vals.len() {
                let (Some(&c), Some(&r)) = (col.get(k), row.get(k)) else {
                    return err("spmv col/row shorter than vals");
                };
                let Some(&xv) = x.get(c as usize) else {
                    return err(format!("spmv col index {c} out of bounds"));
                };
                let Some(slot) = out.get_mut(r as usize) else {
                    return err(format!("spmv row index {r} out of bounds"));
                };
                *slot += vals[k] * xv;
            }
            Ok(out)
        }

        fn check_shapes(&self, data: usize, idx: usize) -> Result<()> {
            if data != self.shapes.data_n || idx != self.shapes.tile {
                err(format!(
                    "shape mismatch: data {data} (want {}), idx {idx} (want {})",
                    self.shapes.data_n, self.shapes.tile
                ))
            } else {
                Ok(())
            }
        }
    }

    /// Walk up from the current directory to find `artifacts/manifest.txt`.
    pub fn find_artifacts() -> Result<PathBuf> {
        let mut dir = std::env::current_dir()
            .map_err(|e| RuntimeError(format!("current dir: {e}")))?;
        loop {
            let cand = dir.join(ARTIFACT_DIR);
            if cand.join("manifest.txt").exists() {
                return Ok(cand);
            }
            if !dir.pop() {
                return err("artifacts/manifest.txt not found; run `make artifacts` first");
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        fn rt(tile: usize, data_n: usize) -> TileRuntime {
            TileRuntime {
                names: vec!["gather_f32".to_string()],
                shapes: TileShapes {
                    tile,
                    data_n,
                    range_cap: 4 * tile,
                },
            }
        }

        #[test]
        fn native_gather_matches_reference() {
            let r = rt(4, 8);
            let data: Vec<f32> = (0..8).map(|i| i as f32).collect();
            let out = r.gather_f32(&data, &[3, 0, 7, 7]).unwrap();
            assert_eq!(out, vec![3.0, 0.0, 7.0, 7.0]);
            assert!(r.gather_f32(&data, &[8, 0, 0, 0]).is_err());
            assert!(r.gather_f32(&data[..4], &[0, 1, 2, 3]).is_err());
        }

        #[test]
        fn native_scatter_semantics() {
            let r = rt(3, 4);
            let data = vec![0.0f32; 4];
            let add = r.scatter_add_f32(&data, &[1, 1, 3], &[2.0, 3.0, 4.0]).unwrap();
            assert_eq!(add, vec![0.0, 5.0, 0.0, 4.0]);
            let set = r.scatter_set_f32(&data, &[1, 1, 3], &[2.0, 3.0, 4.0]).unwrap();
            assert_eq!(set, vec![0.0, 3.0, 0.0, 4.0]);
        }

        #[test]
        fn native_spmv_tile() {
            let r = rt(2, 4);
            // y[row[k]] += vals[k] * x[col[k]]
            let out = r
                .spmv_tile_f32(&[2.0, 3.0], &[0, 1], &[1, 1], &[10.0, 20.0], &[0.0, 1.0])
                .unwrap();
            assert_eq!(out, vec![0.0, 1.0 + 2.0 * 10.0 + 3.0 * 20.0]);
        }

        #[test]
        fn manifest_header_parses() {
            let s = parse_shapes("tile=128 data_n=1024 range_cap=512 junk x=y").unwrap();
            assert_eq!((s.tile, s.data_n, s.range_cap), (128, 1024, 512));
            let d = parse_shapes("").unwrap();
            assert_eq!(d.tile, 4096);
            assert!(parse_shapes("tile=8k").is_err());
        }
    }
}

#[cfg(feature = "pjrt")]
mod backend {
    use super::{parse_shapes, TileShapes, ARTIFACT_DIR};
    use anyhow::{anyhow, Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// Runtime holding compiled executables for every artifact.
    pub struct TileRuntime {
        client: xla::PjRtClient,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
        /// Shapes baked into the loaded artifacts.
        pub shapes: TileShapes,
    }

    impl TileRuntime {
        /// Load every artifact in `dir` (compiling each HLO once).
        pub fn load(dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT: {e:?}"))?;
            let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
                .with_context(|| format!("missing manifest in {dir:?}; run `make artifacts`"))?;
            let shapes = parse_shapes(manifest.lines().next().unwrap_or_default())
                .map_err(|e| anyhow!("{e}"))?;
            let mut exes = HashMap::new();
            for line in manifest.lines().skip(1) {
                let Some(name) = line.split_whitespace().next() else {
                    continue;
                };
                let path = dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("bad path"))?,
                )
                .map_err(|e| anyhow!("parse {name}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
                exes.insert(name.to_string(), exe);
            }
            Ok(TileRuntime {
                client,
                exes,
                shapes,
            })
        }

        /// Load from the conventional `artifacts/` directory next to the
        /// current working directory (or its parents).
        pub fn load_default() -> Result<Self> {
            Self::load(&find_artifacts()?)
        }

        /// Human-readable backend description (the PJRT platform).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Whether artifact `name` was compiled from the manifest.
        pub fn has(&self, name: &str) -> bool {
            self.exes.contains_key(name)
        }

        /// Sorted artifact names from the manifest.
        pub fn names(&self) -> Vec<&str> {
            let mut v: Vec<&str> = self.exes.keys().map(|s| s.as_str()).collect();
            v.sort();
            v
        }

        /// Execute artifact `name` with the given literals; returns the tuple
        /// elements of the result.
        pub fn execute(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let exe = self
                .exes
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
            let out = exe
                .execute::<xla::Literal>(args)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
            let lit = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("sync {name}: {e:?}"))?;
            let tuple = lit.to_tuple().map_err(|e| anyhow!("tuple {name}: {e:?}"))?;
            Ok(tuple)
        }

        /// `out[i] = data[idx[i]]` via the Pallas gather artifact.
        pub fn gather_f32(&self, data: &[f32], idx: &[i32]) -> Result<Vec<f32>> {
            self.check_shapes(data.len(), idx.len())?;
            let out = self.execute(
                "gather_f32",
                &[xla::Literal::vec1(data), xla::Literal::vec1(idx)],
            )?;
            Ok(out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?)
        }

        /// `data[idx[i]] += vals[i]` (duplicates accumulate).
        pub fn scatter_add_f32(&self, data: &[f32], idx: &[i32], vals: &[f32]) -> Result<Vec<f32>> {
            self.check_shapes(data.len(), idx.len())?;
            let out = self.execute(
                "scatter_add_f32",
                &[
                    xla::Literal::vec1(data),
                    xla::Literal::vec1(idx),
                    xla::Literal::vec1(vals),
                ],
            )?;
            Ok(out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?)
        }

        /// `data[idx[i]] = vals[i]` (last write wins).
        pub fn scatter_set_f32(&self, data: &[f32], idx: &[i32], vals: &[f32]) -> Result<Vec<f32>> {
            self.check_shapes(data.len(), idx.len())?;
            let out = self.execute(
                "scatter_set_f32",
                &[
                    xla::Literal::vec1(data),
                    xla::Literal::vec1(idx),
                    xla::Literal::vec1(vals),
                ],
            )?;
            Ok(out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?)
        }

        /// One SpMV tile: `y[row[k]] += vals[k] * x[col[k]]`.
        pub fn spmv_tile_f32(
            &self,
            vals: &[f32],
            col: &[i32],
            row: &[i32],
            x: &[f32],
            y: &[f32],
        ) -> Result<Vec<f32>> {
            let out = self.execute(
                "spmv_tile_f32",
                &[
                    xla::Literal::vec1(vals),
                    xla::Literal::vec1(col),
                    xla::Literal::vec1(row),
                    xla::Literal::vec1(x),
                    xla::Literal::vec1(y),
                ],
            )?;
            Ok(out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?)
        }

        fn check_shapes(&self, data: usize, idx: usize) -> Result<()> {
            if data != self.shapes.data_n || idx != self.shapes.tile {
                Err(anyhow!(
                    "shape mismatch: data {data} (want {}), idx {idx} (want {})",
                    self.shapes.data_n,
                    self.shapes.tile
                ))
            } else {
                Ok(())
            }
        }
    }

    /// Walk up from the current directory to find `artifacts/manifest.txt`.
    pub fn find_artifacts() -> Result<PathBuf> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join(ARTIFACT_DIR);
            if cand.join("manifest.txt").exists() {
                return Ok(cand);
            }
            if !dir.pop() {
                return Err(anyhow!(
                    "artifacts/manifest.txt not found; run `make artifacts` first"
                ));
            }
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use backend::RuntimeError;
pub use backend::{find_artifacts, TileRuntime};
