//! Minimal property-testing kit (no external crates are available offline):
//! a deterministic case runner over seeded generators with failure-seed
//! reporting, plus scenario-space generators for the differential fuzzer.
//! Used by `rust/tests/prop_*.rs` for coordinator invariants and by
//! [`crate::engine::fuzz`].

use crate::util::Rng;

/// Run `n` property cases. Each case gets a fresh deterministic [`Rng`];
/// on panic the failing seed is reported so the case can be replayed.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, n: u64, f: F) {
    for case in 0..n {
        let seed = 0x9E3779B9_7F4A7C15u64 ^ (case.wrapping_mul(0xBF58476D1CE4E5B9));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property '{name}' failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Generator helpers over [`Rng`].
pub mod gen {
    use crate::util::Rng;

    /// Vector of `n` values in `[0, bound)`.
    pub fn indices(rng: &mut Rng, n: usize, bound: usize) -> Vec<u32> {
        (0..n).map(|_| rng.below(bound as u64) as u32).collect()
    }

    /// Vector of `n` f32 in [0, 1).
    pub fn f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.f32()).collect()
    }

    /// Monotone offsets array with spans in `[0, max_span]`.
    pub fn offsets(rng: &mut Rng, n: usize, max_span: u64) -> Vec<u32> {
        let mut out = Vec::with_capacity(n + 1);
        let mut acc = 0u32;
        out.push(0);
        for _ in 0..n {
            acc += rng.below(max_span + 1) as u32;
            out.push(acc);
        }
        out
    }

    /// A size in [1, max], biased toward small and boundary values.
    pub fn size(rng: &mut Rng, max: usize) -> usize {
        match rng.below(4) {
            0 => 1 + rng.below_usize(4.min(max)),
            1 => max,
            _ => 1 + rng.below_usize(max),
        }
    }
}

/// Random-scenario generators over the `workloads::synth` space: a
/// deterministic [`Rng`] samples an index distribution, an access shape,
/// and the size/locality knobs, yielding a [`ScenarioSpec`] that lowers
/// through the registry path like any named scenario. One seed pins the
/// sampled spec *and* its realized memory, so a failing fuzz case is a
/// single u64 away from replay ([`crate::engine::fuzz`]).
pub mod scenario {
    use crate::dx100::isa::{DType, Op};
    use crate::util::Rng;
    use crate::workloads::synth::{AccessShape, IndexDist, PatternSpec, ScenarioSpec};

    /// Stride tables for [`IndexDist::Runs`] (the enum wants `'static`).
    const STRIDE_SETS: [&[u64]; 3] = [&[1, 1, 2, 4], &[1], &[2, 4, 8]];

    /// Sample an index distribution: (stable label, distribution).
    pub fn index_dist(rng: &mut Rng) -> (&'static str, IndexDist) {
        match rng.below(5) {
            0 => ("uni", IndexDist::Uniform),
            1 => (
                "zipf",
                IndexDist::Zipf {
                    theta: *rng.pick(&[0.6, 0.8, 0.99]),
                },
            ),
            2 => {
                let min_run = 4 + rng.below(12);
                (
                    "runs",
                    IndexDist::Runs {
                        min_run,
                        max_run: min_run + 1 + rng.below(60),
                        strides: rng.pick(&STRIDE_SETS),
                    },
                )
            }
            3 => ("chase", IndexDist::Chase),
            _ => (
                "hash",
                IndexDist::Hashed {
                    buckets: *rng.pick(&[64usize, 256, 1024]),
                },
            ),
        }
    }

    /// Sample an access shape: (stable label, shape).
    pub fn access_shape(rng: &mut Rng) -> (&'static str, AccessShape) {
        match rng.below(5) {
            0 => ("gather", AccessShape::Gather),
            1 => ("scatter", AccessShape::Scatter),
            2 => (
                "rmw",
                AccessShape::Rmw {
                    op: *rng.pick(&[Op::Add, Op::Min, Op::Max]),
                    atomic: rng.chance(0.5),
                },
            ),
            3 => (
                "cond",
                AccessShape::Conditional {
                    density: *rng.pick(&[0.1, 0.25, 0.5, 0.9]),
                },
            ),
            _ => ("2lvl", AccessShape::TwoLevel),
        }
    }

    /// Sample a complete scenario. Sizes are kept small (256–1024 base
    /// stream over a 4K–16K target) so a fuzz batch of hundreds of cases
    /// stays CI-affordable; `seed` pins the sampled knobs, the realized
    /// index stream, and the scenario's unique name
    /// (`fz-<dist>-<shape>-<seed>`).
    pub fn scenario_spec(rng: &mut Rng, seed: u64) -> ScenarioSpec {
        let (dlabel, dist) = index_dist(rng);
        let (slabel, shape) = access_shape(rng);
        let mut pattern = PatternSpec::new(dist, seed)
            .with_stream(256usize << rng.below(3))
            .with_target(4096usize << rng.below(3))
            .with_dup(*rng.pick(&[0.0, 0.0, 0.25, 0.5, 0.75]));
        if rng.chance(0.25) {
            pattern = pattern.with_hot(0.1, 0.9);
        }
        if rng.chance(0.2) {
            pattern = pattern.with_dtype(DType::F64);
        }
        ScenarioSpec::new(&format!("fz-{dlabel}-{slabel}-{seed:016x}"), pattern, shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = std::sync::atomic::AtomicU64::new(0);
        let c = &mut count;
        // Interior mutability via atomic since F is Fn.
        let counter = std::sync::atomic::AtomicU64::new(0);
        check("counts", 10, |_rng| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 10);
        let _ = c;
    }

    #[test]
    fn generators_produce_valid_shapes() {
        let mut rng = Rng::new(1);
        let idx = gen::indices(&mut rng, 100, 50);
        assert!(idx.iter().all(|&i| i < 50));
        let off = gen::offsets(&mut rng, 10, 5);
        assert_eq!(off.len(), 11);
        assert!(off.windows(2).all(|w| w[0] <= w[1]));
        for _ in 0..100 {
            let s = gen::size(&mut rng, 64);
            assert!((1..=64).contains(&s));
        }
    }

    #[test]
    fn scenario_sampling_is_deterministic_and_buildable() {
        use crate::compiler::analyze;
        use crate::workloads::Scale;
        for case in 0..8u64 {
            let seed = 0xFA2E ^ case;
            let a = scenario::scenario_spec(&mut Rng::new(seed), seed);
            let b = scenario::scenario_spec(&mut Rng::new(seed), seed);
            assert!(std::ptr::eq(a.name, b.name), "names must intern equal");
            let wa = a.build(Scale::test());
            let wb = b.build(Scale::test());
            assert_eq!(wa.mem.stable_hash(), wb.mem.stable_hash(), "{}", a.name);
            let (an, legal) = analyze(&wa.program);
            assert!(legal.is_ok(), "{}: {:?}", a.name, legal.err());
            assert!(an.max_indirection >= 1, "{}", a.name);
            assert!(wa.validate_bounds().is_ok(), "{}", a.name);
        }
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        check("fails", 5, |rng| {
            assert!(rng.below(10) < 100); // always true
            panic!("boom");
        });
    }
}
