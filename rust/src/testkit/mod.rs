//! Minimal property-testing kit (no external crates are available offline):
//! a deterministic case runner over seeded generators with failure-seed
//! reporting. Used by `rust/tests/prop_*.rs` for coordinator invariants.

use crate::util::Rng;

/// Run `n` property cases. Each case gets a fresh deterministic [`Rng`];
/// on panic the failing seed is reported so the case can be replayed.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, n: u64, f: F) {
    for case in 0..n {
        let seed = 0x9E3779B9_7F4A7C15u64 ^ (case.wrapping_mul(0xBF58476D1CE4E5B9));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property '{name}' failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Generator helpers over [`Rng`].
pub mod gen {
    use crate::util::Rng;

    /// Vector of `n` values in `[0, bound)`.
    pub fn indices(rng: &mut Rng, n: usize, bound: usize) -> Vec<u32> {
        (0..n).map(|_| rng.below(bound as u64) as u32).collect()
    }

    /// Vector of `n` f32 in [0, 1).
    pub fn f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.f32()).collect()
    }

    /// Monotone offsets array with spans in `[0, max_span]`.
    pub fn offsets(rng: &mut Rng, n: usize, max_span: u64) -> Vec<u32> {
        let mut out = Vec::with_capacity(n + 1);
        let mut acc = 0u32;
        out.push(0);
        for _ in 0..n {
            acc += rng.below(max_span + 1) as u32;
            out.push(acc);
        }
        out
    }

    /// A size in [1, max], biased toward small and boundary values.
    pub fn size(rng: &mut Rng, max: usize) -> usize {
        match rng.below(4) {
            0 => 1 + rng.below_usize(4.min(max)),
            1 => max,
            _ => 1 + rng.below_usize(max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = std::sync::atomic::AtomicU64::new(0);
        let c = &mut count;
        // Interior mutability via atomic since F is Fn.
        let counter = std::sync::atomic::AtomicU64::new(0);
        check("counts", 10, |_rng| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 10);
        let _ = c;
    }

    #[test]
    fn generators_produce_valid_shapes() {
        let mut rng = Rng::new(1);
        let idx = gen::indices(&mut rng, 100, 50);
        assert!(idx.iter().all(|&i| i < 50));
        let off = gen::offsets(&mut rng, 10, 5);
        assert_eq!(off.len(), 11);
        assert!(off.windows(2).all(|w| w[0] <= w[1]));
        for _ in 0..100 {
            let s = gen::size(&mut rng, 64);
            assert!((1..=64).contains(&s));
        }
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        check("fails", 5, |rng| {
            assert!(rng.below(10) < 100); // always true
            panic!("boom");
        });
    }
}
