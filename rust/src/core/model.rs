//! The core timing model: an instruction window with issue-width, ROB,
//! LQ/SQ, MSHR, dependency and fence constraints driving the cache
//! hierarchy and DRAM.
//!
//! The model is event-driven: `wake` is called whenever something this core
//! cares about might have changed (an op completed, a timer expired). Each
//! wake retires finished ops in order, refills the ROB from the op stream,
//! and issues ready ops — scanning at most `IQ_SCAN` waiting entries, the
//! analog of the Table 3 50-entry issue queue.
//!
//! # Lane discipline
//!
//! A wake runs entirely against **lane-local** state ([`LaneEnv`]): this
//! core's [`PrivateLane`] caches, its stride prefetcher, its own event
//! queue, and a read-only snapshot of the DX100 ready flags. Work that
//! needs a shared resource — the LLC, the DRAM controller, MMIO delivery,
//! prefetch reservations — is not performed here; it is recorded as a
//! timestamped [`LaneAction`] and applied later by the coordinator's
//! shared stage in a deterministic core-index-ordered merge. That seam is
//! what lets several cores' front ends advance in parallel inside one
//! time quantum with bit-identical results at any fan-out (see
//! `docs/CONCURRENCY.md`).

use super::ops::{Op, OpKind};
use crate::cache::{PrivateAccess, PrivateLane, StridePrefetcher};
use crate::config::CoreConfig;
use crate::sim::{Cycle, Event, EventQueue};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

/// Issue-queue scan bound per wake (Table 3: IQ = 50).
const IQ_SCAN: usize = 50;
/// Extra latency applied to an atomic RMW after its data arrives
/// (cacheline locking / fence drain, per [4] Free Atomics discussion).
const ATOMIC_LOCK_PENALTY: Cycle = 24;
/// Plain (non-atomic) RMW modify latency after data arrives.
const RMW_MODIFY_LATENCY: Cycle = 2;

/// Per-core execution statistics.
#[derive(Clone, Debug, Default)]
pub struct CoreStats {
    /// Dynamic instructions retired.
    pub retired_instrs: u64,
    /// Load ops issued.
    pub loads: u64,
    /// Store ops issued.
    pub stores: u64,
    /// Read-modify-write ops issued.
    pub rmws: u64,
    /// Spin-wait instructions (included in `retired_instrs`).
    pub spin_instrs: u64,
    /// Cycle the core retired its last op.
    pub finish_time: Cycle,
}

/// Map from in-flight line address to the (core, stream index) ops waiting
/// on it — primary misses and MSHR-merged secondaries alike.
pub type LineWaiters = HashMap<u64, Vec<(usize, usize)>>;

/// A DX100 instruction delivery produced by a completed MMIO store triple.
#[derive(Clone, Copy, Debug)]
pub struct MmioDelivery {
    /// Target DX100 instance.
    pub instance: u16,
    /// Instruction sequence number being delivered.
    pub seq: u32,
    /// Cycle the store lands at the accelerator.
    pub time: Cycle,
}

/// Book-keeping the system keeps for an outstanding core DRAM request.
#[derive(Clone, Copy, Debug)]
pub struct PendingMem {
    /// Core that issued the request.
    pub core: usize,
    /// Stream index of the waiting op.
    pub stream_idx: usize,
}

/// One shared-resource interaction deferred from a lane wake to the
/// coordinator's shared stage. Ordered within a lane by emission; the
/// shared stage merges lanes by `(time, core index, emission order)`.
#[derive(Clone, Copy, Debug)]
pub struct LaneAction {
    /// Event time of the wake that produced the action.
    pub time: Cycle,
    /// What the shared stage must do.
    pub kind: LaneActionKind,
}

/// The shared-stage work items a lane can emit.
#[derive(Clone, Copy, Debug)]
pub enum LaneActionKind {
    /// A demand access that missed the private L1/L2 and holds an MSHR
    /// reservation; the shared stage resolves it against the LLC / DRAM
    /// via [`crate::cache::Hierarchy::shared_access`].
    Access {
        /// Stream index of the waiting op (completion routing).
        stream_idx: usize,
        /// Byte address.
        addr: u64,
        /// Whether the access dirties the line.
        is_write: bool,
        /// Issue cycle the core allocated (bandwidth-accounted); latencies
        /// accumulate from here.
        issue_at: Cycle,
    },
    /// A private-level write hit: mark the line dirty for writeback
    /// accounting (no completion needed).
    Dirty {
        /// Line address to mark.
        line: u64,
    },
    /// A stride-prefetcher candidate line; the shared stage filters it
    /// against the LLC, reserves MSHRs, and enqueues the DRAM read.
    Prefetch {
        /// Candidate line address.
        line: u64,
    },
    /// A DMP indirect-prefetch hint attached to the issued op.
    DmpHint {
        /// Predicted byte address.
        addr: u64,
    },
    /// A completed MMIO store triple: deliver instruction `seq` to
    /// `instance` at `at`.
    Mmio {
        /// Target DX100 instance.
        instance: u16,
        /// Instruction sequence number.
        seq: u32,
        /// Delivery time at the accelerator.
        at: Cycle,
    },
}

/// Lane-local environment handed to the core on each wake. Everything
/// here is private to the core (or an immutable snapshot), so wakes of
/// different cores can run on different worker threads.
pub struct LaneEnv<'a> {
    /// This core's private L1/L2 caches and MSHR files.
    pub lane: &'a mut PrivateLane,
    /// This core's own event queue (self-scheduled wakes only).
    pub queue: &'a mut EventQueue,
    /// This core's stride prefetcher.
    pub prefetcher: &'a mut StridePrefetcher,
    /// Ready-bit board snapshot of each DX100 instance:
    /// `flags[instance][flag]`, as of the current merge round.
    pub flags: &'a [Vec<bool>],
    /// Deferred shared-stage work, appended in emission order.
    pub actions: &'a mut Vec<LaneAction>,
    /// Effective scratchpad read latency (cacheable + stride-prefetched).
    pub spd_latency: Cycle,
    /// Uncacheable MMIO store latency.
    pub mmio_latency: Cycle,
    /// DMP indirect-prefetcher hints for this core (op idx -> prefetch
    /// address); `None` when the system has no indirect prefetcher.
    pub dmp_hints: Option<&'a crate::prefetch::DmpHints>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EState {
    Waiting,
    Issued,
    Done,
}

#[derive(Clone, Copy, Debug)]
struct RobEntry {
    stream_idx: usize,
    op: Op,
    state: EState,
}

/// One modeled core.
pub struct CoreModel {
    /// Core index.
    pub id: usize,
    cfg: CoreConfig,
    next_op: usize,
    rob: VecDeque<RobEntry>,
    rob_instrs: u32,
    loads_inflight: u32,
    stores_inflight: u32,
    fence_active: bool,
    issue_time: Cycle,
    slots_left: u32,
    pending_done: BinaryHeap<Reverse<(Cycle, usize)>>,
    /// Execution statistics.
    pub stats: CoreStats,
    /// Whether every op of the stream has retired.
    pub done: bool,
    /// Set when an access bounced off a full MSHR; the system re-wakes
    /// blocked cores on every completion.
    pub blocked: bool,
    spin_interval: Cycle,
    spin_instrs_per_poll: u16,
    /// Earliest pending self-scheduled `CoreWake` (dedup guard).
    next_wake_at: Cycle,
}

impl CoreModel {
    /// A fresh core with an empty window.
    pub fn new(id: usize, cfg: CoreConfig) -> Self {
        CoreModel {
            id,
            cfg,
            next_op: 0,
            rob: VecDeque::new(),
            rob_instrs: 0,
            loads_inflight: 0,
            stores_inflight: 0,
            fence_active: false,
            issue_time: 0,
            slots_left: 0,
            pending_done: BinaryHeap::new(),
            stats: CoreStats::default(),
            done: false,
            blocked: false,
            spin_interval: 60,
            spin_instrs_per_poll: 4,
            next_wake_at: Cycle::MAX,
        }
    }

    /// Dedup guard for self-scheduled wakes.
    fn request_wake(&mut self, t: Cycle) -> bool {
        if t < self.next_wake_at {
            self.next_wake_at = t;
            true
        } else {
            false
        }
    }

    /// Allocate issue bandwidth for `instrs` instructions at or after `t`;
    /// returns the cycle the op issues.
    fn alloc_issue(&mut self, t: Cycle, instrs: u16) -> Cycle {
        if self.issue_time < t {
            self.issue_time = t;
            self.slots_left = self.cfg.issue_width;
        }
        let at = self.issue_time;
        let mut need = instrs as u32;
        while need >= self.slots_left {
            need -= self.slots_left;
            self.issue_time += 1;
            self.slots_left = self.cfg.issue_width;
        }
        self.slots_left -= need;
        at
    }

    /// Mark a memory op complete (called on DRAM return / merged-line fill
    /// / shared-stage LLC hit). Returns the time the op's result is
    /// architecturally ready (RMW adds modify / lock latency); the caller
    /// schedules a `CoreWake` then.
    pub fn complete_mem(&mut self, stream_idx: usize, t: Cycle) -> Cycle {
        let penalty = self
            .rob_entry(stream_idx)
            .map(|e| match e.op.kind {
                OpKind::Rmw { atomic: true, .. } => ATOMIC_LOCK_PENALTY + RMW_MODIFY_LATENCY,
                OpKind::Rmw { atomic: false, .. } => RMW_MODIFY_LATENCY,
                _ => 0,
            })
            .unwrap_or(0);
        let done_at = t + penalty;
        self.pending_done.push(Reverse((done_at, stream_idx)));
        done_at
    }

    fn rob_entry(&self, stream_idx: usize) -> Option<&RobEntry> {
        let front = self.rob.front()?.stream_idx;
        if stream_idx < front {
            return None;
        }
        self.rob.get(stream_idx - front)
    }

    fn dep_satisfied(&self, e: &RobEntry) -> bool {
        if e.op.dep == 0 {
            return true;
        }
        let target = e.stream_idx as u64 - e.op.dep as u64;
        let front = match self.rob.front() {
            Some(f) => f.stream_idx as u64,
            None => return true,
        };
        if target < front {
            return true; // already retired
        }
        matches!(
            self.rob[(target - front) as usize].state,
            EState::Done
        )
    }

    /// Main state machine. Call on every `CoreWake(self.id)` event.
    pub fn wake(&mut self, t: Cycle, ops: &[Op], env: &mut LaneEnv) {
        self.blocked = false;
        if self.next_wake_at <= t {
            self.next_wake_at = Cycle::MAX;
        }
        // 1. Apply matured completions.
        while let Some(&Reverse((when, idx))) = self.pending_done.peek() {
            if when > t {
                break;
            }
            self.pending_done.pop();
            if let Some(front) = self.rob.front().map(|f| f.stream_idx) {
                if idx >= front {
                    let e = &mut self.rob[idx - front];
                    debug_assert_ne!(e.state, EState::Waiting, "completing unissued op");
                    e.state = EState::Done;
                }
            }
        }
        // 2. In-order retire.
        while let Some(front) = self.rob.front() {
            if front.state != EState::Done {
                break;
            }
            let e = self.rob.pop_front().unwrap();
            self.rob_instrs -= e.op.instrs as u32;
            self.stats.retired_instrs += e.op.instrs as u64;
            if e.op.is_load() {
                self.loads_inflight -= 1;
            }
            if e.op.is_store() {
                self.stores_inflight -= 1;
            }
            if matches!(e.op.kind, OpKind::Rmw { atomic: true, .. }) {
                self.fence_active = false;
            }
        }
        // 3. Refill ROB.
        while self.next_op < ops.len() {
            let op = ops[self.next_op];
            if self.rob_instrs + op.instrs as u32 > self.cfg.rob && !self.rob.is_empty() {
                break;
            }
            self.rob.push_back(RobEntry {
                stream_idx: self.next_op,
                op,
                state: EState::Waiting,
            });
            self.rob_instrs += op.instrs as u32;
            self.next_op += 1;
        }
        // 4. Issue ready ops (bounded scan).
        let mut scanned = 0usize;
        for i in 0..self.rob.len() {
            if scanned >= IQ_SCAN {
                break;
            }
            if self.rob[i].state != EState::Waiting {
                continue;
            }
            scanned += 1;
            let e = self.rob[i];
            if !self.dep_satisfied(&e) {
                continue;
            }
            // Structural constraints.
            if e.op.is_mem() && self.fence_active {
                continue;
            }
            if e.op.is_load() && self.loads_inflight >= self.cfg.lq {
                continue;
            }
            if e.op.is_store() && self.stores_inflight >= self.cfg.sq {
                continue;
            }
            if let OpKind::Rmw { atomic: true, .. } = e.op.kind {
                // Fence semantics: issue only from the ROB head (all older
                // ops retired); `fence_active` then blocks younger memory
                // ops until the atomic completes. Younger loads that issued
                // before the atomic reached the head are allowed to drain
                // (they would be replayed in real hardware).
                if i != 0 {
                    continue;
                }
            }
            match self.try_issue(i, t, env) {
                IssueResult::Issued => {}
                IssueResult::Stalled => {}
                IssueResult::Blocked => {
                    self.blocked = true;
                }
            }
        }
        // 5. Done check.
        if self.next_op >= ops.len() && self.rob.is_empty() && !self.done {
            self.done = true;
            self.stats.finish_time = t;
        }
        // 6. Next self-wake for known-future completions.
        if let Some(&Reverse((when, _))) = self.pending_done.peek() {
            let when = when.max(t);
            if self.request_wake(when) {
                env.queue.push(when, Event::CoreWake(self.id));
            }
        }
    }

    fn try_issue(&mut self, i: usize, t: Cycle, env: &mut LaneEnv) -> IssueResult {
        let e = self.rob[i];
        let idx = e.stream_idx;
        match e.op.kind {
            OpKind::Compute { cycles } => {
                let at = self.alloc_issue(t, e.op.instrs);
                self.rob[i].state = EState::Issued;
                self.pending_done.push(Reverse((at + cycles as Cycle, idx)));
                IssueResult::Issued
            }
            OpKind::SpdLoad => {
                let at = self.alloc_issue(t, e.op.instrs);
                self.rob[i].state = EState::Issued;
                self.loads_inflight += 1;
                self.stats.loads += 1;
                self.pending_done.push(Reverse((at + env.spd_latency, idx)));
                IssueResult::Issued
            }
            OpKind::MmioStore { instance, seq } => {
                let at = self.alloc_issue(t, e.op.instrs);
                self.rob[i].state = EState::Issued;
                self.stores_inflight += 1;
                self.stats.stores += 1;
                let done = at + env.mmio_latency;
                env.actions.push(LaneAction {
                    time: t,
                    kind: LaneActionKind::Mmio {
                        instance,
                        seq,
                        at: done,
                    },
                });
                self.pending_done.push(Reverse((done, idx)));
                IssueResult::Issued
            }
            OpKind::WaitFlag { instance, flag } => {
                if env.flags[instance as usize][flag as usize] {
                    let at = self.alloc_issue(t, e.op.instrs);
                    self.rob[i].state = EState::Issued;
                    self.pending_done.push(Reverse((at + 1, idx)));
                    IssueResult::Issued
                } else {
                    // Spin: burn poll instructions and retry later.
                    self.stats.spin_instrs += self.spin_instrs_per_poll as u64;
                    let when = t + self.spin_interval;
                    if self.request_wake(when) {
                        env.queue.push(when, Event::CoreWake(self.id));
                    }
                    IssueResult::Stalled
                }
            }
            OpKind::Load { addr, stream } | OpKind::Store { addr, stream } => {
                let is_store = matches!(e.op.kind, OpKind::Store { .. });
                self.issue_mem(i, t, addr, stream, is_store, false, env)
            }
            OpKind::Rmw { addr, atomic } => self.issue_mem(i, t, addr, 0, true, atomic, env),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn issue_mem(
        &mut self,
        i: usize,
        t: Cycle,
        addr: u64,
        stream: u32,
        is_write: bool,
        is_rmw_like: bool,
        env: &mut LaneEnv,
    ) -> IssueResult {
        let e = self.rob[i];
        let idx = e.stream_idx;
        match env.lane.access_private(addr, t) {
            PrivateAccess::Blocked => IssueResult::Blocked,
            PrivateAccess::Hit { latency, .. } => {
                let at = self.alloc_issue(t, e.op.instrs);
                self.mark_issued_mem(i, is_write, is_rmw_like);
                if is_write {
                    env.actions.push(LaneAction {
                        time: t,
                        kind: LaneActionKind::Dirty { line: addr >> 6 },
                    });
                }
                let extra = if is_rmw_like {
                    if matches!(e.op.kind, OpKind::Rmw { atomic: true, .. }) {
                        ATOMIC_LOCK_PENALTY + RMW_MODIFY_LATENCY
                    } else {
                        RMW_MODIFY_LATENCY
                    }
                } else {
                    0
                };
                self.pending_done.push(Reverse((at + latency + extra, idx)));
                self.observe_prefetch(addr, stream, t, env);
                self.fire_dmp_hint(idx, t, env);
                IssueResult::Issued
            }
            PrivateAccess::Miss => {
                // The lane reserved MSHR room; the shared stage settles the
                // access (LLC hit, merge, DRAM miss, or parked retry) and
                // wakes this core when data is ready.
                let at = self.alloc_issue(t, e.op.instrs);
                self.mark_issued_mem(i, is_write, is_rmw_like);
                env.actions.push(LaneAction {
                    time: t,
                    kind: LaneActionKind::Access {
                        stream_idx: idx,
                        addr,
                        is_write,
                        issue_at: at,
                    },
                });
                self.observe_prefetch(addr, stream, t, env);
                self.fire_dmp_hint(idx, t, env);
                IssueResult::Issued
            }
        }
    }

    fn mark_issued_mem(&mut self, i: usize, is_write: bool, is_rmw_like: bool) {
        self.rob[i].state = EState::Issued;
        let op = self.rob[i].op;
        if op.is_load() {
            self.loads_inflight += 1;
            self.stats.loads += 1;
        }
        if op.is_store() {
            self.stores_inflight += 1;
            if !is_rmw_like {
                self.stats.stores += 1;
            }
        }
        if is_rmw_like && is_write {
            self.stats.rmws += 1;
        }
        if let OpKind::Rmw { atomic: true, .. } = op.kind {
            self.fence_active = true;
        }
    }

    /// Emit the DMP indirect prefetch attached to op `idx`, if any: the
    /// predicted `A[B[i+d]]` line goes through the shared stage's
    /// L2/LLC prefetch path.
    fn fire_dmp_hint(&mut self, idx: usize, t: Cycle, env: &mut LaneEnv) {
        let Some(hints) = env.dmp_hints else { return };
        let Some(&addr) = hints.get(&idx) else { return };
        if env.lane.l2.contains(addr >> 6) {
            return;
        }
        env.actions.push(LaneAction {
            time: t,
            kind: LaneActionKind::DmpHint { addr },
        });
    }

    fn observe_prefetch(&mut self, addr: u64, stream: u32, t: Cycle, env: &mut LaneEnv) {
        if stream == 0 {
            return;
        }
        let key = ((self.id as u64) << 32) | stream as u64;
        let lines = env.prefetcher.observe(key, addr >> 6);
        for line in lines {
            if env.lane.l2.contains(line) {
                continue;
            }
            env.actions.push(LaneAction {
                time: t,
                kind: LaneActionKind::Prefetch { line },
            });
        }
    }

    /// Serialize the full core micro-state. ROB entries store only their
    /// stream index and execution state — the op itself is refetched from
    /// the (immutable) compiled stream at load. `pending_done` is written
    /// in sorted `(time, idx)` order; heap layout is not observable.
    pub(crate) fn save(&self, e: &mut crate::engine::snapshot::Enc) {
        e.usize(self.next_op);
        e.usize(self.rob.len());
        for r in &self.rob {
            e.usize(r.stream_idx);
            e.u8(match r.state {
                EState::Waiting => 0,
                EState::Issued => 1,
                EState::Done => 2,
            });
        }
        e.u32(self.rob_instrs);
        e.u32(self.loads_inflight);
        e.u32(self.stores_inflight);
        e.bool(self.fence_active);
        e.u64(self.issue_time);
        e.u32(self.slots_left);
        let mut done: Vec<(Cycle, usize)> = self.pending_done.iter().map(|r| r.0).collect();
        done.sort_unstable();
        e.usize(done.len());
        for (when, idx) in done {
            e.u64(when);
            e.usize(idx);
        }
        e.u64(self.stats.retired_instrs);
        e.u64(self.stats.loads);
        e.u64(self.stats.stores);
        e.u64(self.stats.rmws);
        e.u64(self.stats.spin_instrs);
        e.u64(self.stats.finish_time);
        e.bool(self.done);
        e.bool(self.blocked);
        e.u64(self.next_wake_at);
    }

    /// Restore the core against the same compiled op stream it was
    /// snapshotted with; out-of-range ROB indices are typed corruption.
    pub(crate) fn load(
        &mut self,
        d: &mut crate::engine::snapshot::Dec,
        ops: &[Op],
    ) -> Result<(), crate::engine::snapshot::SnapshotError> {
        use crate::engine::snapshot::SnapshotError;
        let oob = |field, idx: usize| SnapshotError::Corrupt {
            field,
            detail: format!("stream index {idx} out of range ({} ops)", ops.len()),
        };
        self.next_op = d.u64("core.next_op")? as usize;
        if self.next_op > ops.len() {
            return Err(oob("core.next_op", self.next_op));
        }
        let n = d.seq_len("core.rob", 9)?;
        self.rob.clear();
        for _ in 0..n {
            let stream_idx = d.u64("core.rob_idx")? as usize;
            let op = *ops.get(stream_idx).ok_or_else(|| oob("core.rob_idx", stream_idx))?;
            let state = match d.u8("core.rob_state")? {
                0 => EState::Waiting,
                1 => EState::Issued,
                2 => EState::Done,
                s => {
                    return Err(SnapshotError::Corrupt {
                        field: "core.rob_state",
                        detail: format!("unknown execution state {s}"),
                    })
                }
            };
            self.rob.push_back(RobEntry {
                stream_idx,
                op,
                state,
            });
        }
        self.rob_instrs = d.u32("core.rob_instrs")?;
        self.loads_inflight = d.u32("core.loads_inflight")?;
        self.stores_inflight = d.u32("core.stores_inflight")?;
        self.fence_active = d.bool("core.fence_active")?;
        self.issue_time = d.u64("core.issue_time")?;
        self.slots_left = d.u32("core.slots_left")?;
        let n = d.seq_len("core.pending_done", 16)?;
        self.pending_done.clear();
        for _ in 0..n {
            let when = d.u64("core.done_time")?;
            let idx = d.u64("core.done_idx")? as usize;
            self.pending_done.push(Reverse((when, idx)));
        }
        self.stats.retired_instrs = d.u64("core.retired_instrs")?;
        self.stats.loads = d.u64("core.loads")?;
        self.stats.stores = d.u64("core.stores")?;
        self.stats.rmws = d.u64("core.rmws")?;
        self.stats.spin_instrs = d.u64("core.spin_instrs")?;
        self.stats.finish_time = d.u64("core.finish_time")?;
        self.done = d.bool("core.done")?;
        self.blocked = d.bool("core.blocked")?;
        self.next_wake_at = d.u64("core.next_wake_at")?;
        Ok(())
    }

    /// Outstanding memory ops (diagnostics).
    pub fn inflight(&self) -> (u32, u32) {
        (self.loads_inflight, self.stores_inflight)
    }

    /// Occupied ROB entries (diagnostics).
    pub fn rob_len(&self) -> usize {
        self.rob.len()
    }
}

enum IssueResult {
    Issued,
    Stalled,
    Blocked,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{Hierarchy, SharedAccess};
    use crate::config::SystemConfig;
    use crate::core::ops::OpStream;
    use crate::mem::{MemController, ReqSource};

    /// Minimal single-core harness driving one CoreModel to completion
    /// through the staged (lane wake + shared apply) discipline.
    struct Harness {
        core: CoreModel,
        hier: Hierarchy,
        mem: MemController,
        queue: EventQueue,
        waiters: LineWaiters,
        prefetcher: StridePrefetcher,
        flags: Vec<Vec<bool>>,
        mmio: Vec<MmioDelivery>,
        ops: Vec<Op>,
        pendings: Vec<(u64, u64, Cycle, ReqSource)>,
    }

    impl Harness {
        fn new(ops: OpStream) -> Self {
            let cfg = SystemConfig::table3();
            Harness {
                core: CoreModel::new(0, cfg.core.clone()),
                hier: Hierarchy::new(&cfg),
                mem: MemController::new(cfg.dram.clone()),
                queue: EventQueue::new(),
                waiters: LineWaiters::new(),
                prefetcher: StridePrefetcher::new(cfg.l1d.prefetch_degree),
                flags: vec![vec![false; 64]],
                mmio: Vec::new(),
                ops: ops.ops,
                pendings: Vec::new(),
            }
        }

        /// One lane wake followed by an inline shared stage (the harness
        /// is single-core, so the merge is trivial).
        fn wake_core(&mut self, t: Cycle) {
            let mut lane = self.hier.take_lane(0);
            let mut actions = Vec::new();
            let mut env = LaneEnv {
                lane: &mut lane,
                queue: &mut self.queue,
                prefetcher: &mut self.prefetcher,
                flags: &self.flags,
                actions: &mut actions,
                spd_latency: 8,
                mmio_latency: 40,
                dmp_hints: None,
            };
            self.core.wake(t, &self.ops, &mut env);
            self.hier.put_lane(0, lane);
            for a in actions {
                self.apply(a);
            }
        }

        fn enqueue_dram(&mut self, start: Cycle, addr: u64, source: ReqSource) {
            self.mem.enqueue(start, addr, false, source);
            let ch = self.mem.channel_of(addr);
            if self.mem.sched_request(ch, start) {
                self.queue.push(start, Event::ChannelSched(ch));
            }
        }

        /// Single-core replica of the coordinator's shared stage — keep in
        /// sync with `System::{settle_access, apply_action}` in
        /// `coordinator/system.rs` (LlcFull parking is omitted: this
        /// harness never saturates the 256-entry LLC MSHR file).
        fn apply(&mut self, a: LaneAction) {
            match a.kind {
                LaneActionKind::Access {
                    stream_idx,
                    addr,
                    is_write,
                    issue_at,
                } => match self.hier.shared_access(0, addr, a.time, is_write) {
                    SharedAccess::LlcHit { latency } => {
                        let at = a.time.max(issue_at + latency);
                        let ready = self.core.complete_mem(stream_idx, at);
                        self.queue.push(ready, Event::CoreWake(0));
                    }
                    SharedAccess::Merged { line } => {
                        self.waiters.entry(line).or_default().push((0, stream_idx));
                    }
                    SharedAccess::Miss { lookup_latency } => {
                        let line = addr >> 6;
                        let start = a.time.max(issue_at + lookup_latency);
                        self.enqueue_dram(
                            start,
                            addr,
                            ReqSource::Core {
                                core: 0,
                                op: stream_idx as u64,
                            },
                        );
                        self.waiters.entry(line).or_default().push((0, stream_idx));
                    }
                    SharedAccess::LlcFull => panic!("harness never fills the LLC MSHRs"),
                },
                LaneActionKind::Dirty { line } => self.hier.mark_dirty(line),
                LaneActionKind::Prefetch { line } => {
                    if !self.hier.llc.contains(line) && self.hier.reserve_prefetch(0, line) {
                        self.enqueue_dram(a.time, line << 6, ReqSource::Prefetch { core: 0 });
                    }
                }
                LaneActionKind::DmpHint { addr } => {
                    let line = addr >> 6;
                    if !self.hier.llc.contains(line) && self.hier.reserve_prefetch(0, line) {
                        self.enqueue_dram(a.time, addr, ReqSource::Prefetch { core: 0 });
                    }
                }
                LaneActionKind::Mmio { instance, seq, at } => self.mmio.push(MmioDelivery {
                    instance,
                    seq,
                    time: at,
                }),
            }
        }

        fn run(&mut self) -> Cycle {
            self.queue.push(0, Event::CoreWake(0));
            let mut t = 0;
            let mut guard = 0u64;
            while let Some(ev) = self.queue.pop() {
                guard += 1;
                assert!(guard < 10_000_000, "harness livelock");
                t = ev.time;
                match ev.event {
                    Event::CoreWake(_) => {
                        self.wake_core(t);
                        if self.core.done {
                            break;
                        }
                    }
                    Event::ChannelSched(ch) => {
                        let (comps, wake) = self.mem.schedule(ch, t);
                        for c in comps {
                            self.queue.push(c.time, Event::DramDone(c.id));
                            self.pendings.push((c.id, c.addr, c.time, c.source));
                        }
                        if let Some(w) = wake {
                            self.queue.push(w, Event::ChannelSched(ch));
                        }
                    }
                    Event::DramDone(id) => {
                        let pos = self
                            .pendings
                            .iter()
                            .position(|p| p.0 == id)
                            .expect("unknown completion");
                        let (_, addr, _, source) = self.pendings.swap_remove(pos);
                        let line = addr >> 6;
                        match source {
                            ReqSource::Core { core, .. } => {
                                self.hier.complete_fill(core, line, t);
                                if let Some(ws) = self.waiters.remove(&line) {
                                    for (c, sidx) in ws {
                                        assert_eq!(c, 0);
                                        let ready = self.core.complete_mem(sidx, t);
                                        self.queue.push(ready, Event::CoreWake(0));
                                    }
                                }
                            }
                            ReqSource::Prefetch { core } => {
                                self.hier.complete_prefetch_fill(core, line, t);
                            }
                            _ => unreachable!(),
                        }
                        if self.core.blocked {
                            self.queue.push(t, Event::CoreWake(0));
                        }
                    }
                    _ => {}
                }
            }
            t
        }
    }

    fn stream_of(ops: Vec<Op>) -> OpStream {
        OpStream { ops }
    }

    #[test]
    fn compute_only_bounded_by_issue_width() {
        // 1000 compute ops of 8 instrs each on an 8-wide core: ~1000 cycles.
        let ops = (0..1000).map(|_| Op::compute(1, 8)).collect();
        let mut h = Harness::new(stream_of(ops));
        let t = h.run();
        assert!(h.core.done);
        assert_eq!(h.core.stats.retired_instrs, 8000);
        assert!((900..2200).contains(&t), "t={t}");
    }

    #[test]
    fn dependent_loads_serialize() {
        // Chain of 64 dependent cache-missing loads: each waits for the
        // previous, so total time ~ 64 * memory latency.
        let mut s = OpStream::new();
        let mut prev: Option<usize> = None;
        for i in 0..64u64 {
            let op = Op::load(i * 1024 * 1024, 0, 1);
            let idx = match prev {
                Some(p) => s.push_dep(op, p),
                None => s.push(op),
            };
            prev = Some(idx);
        }
        let mut h = Harness::new(s);
        let t = h.run();
        assert!(h.core.done);
        // Single miss ~ 58 (lookup) + ~170 (DRAM) cycles; chain of 64 must
        // exceed 64 * 150.
        assert!(t > 64 * 150, "t={t}");
    }

    #[test]
    fn independent_loads_overlap() {
        // 64 independent missing loads spread across banks: MLP-limited,
        // far faster than the same loads chained by dependencies.
        let ops = (0..64u64).map(|i| Op::load(i * 64, 0, 1)).collect();
        let mut h = Harness::new(stream_of(ops));
        let t_indep = h.run();

        let mut s = OpStream::new();
        let mut prev: Option<usize> = None;
        for i in 0..64u64 {
            let op = Op::load(i * 64, 0, 1);
            let idx = match prev {
                Some(p) => s.push_dep(op, p),
                None => s.push(op),
            };
            prev = Some(idx);
        }
        let mut h2 = Harness::new(s);
        let t_dep = h2.run();
        assert!(
            t_dep as f64 > 3.0 * t_indep as f64,
            "dep {t_dep} vs indep {t_indep}"
        );
    }

    #[test]
    fn atomic_rmw_serializes() {
        let atomics: Vec<Op> = (0..200).map(|i| Op::rmw(i * 64, true, 3)).collect();
        let plain: Vec<Op> = (0..200).map(|i| Op::rmw(i * 64, false, 3)).collect();
        let mut ha = Harness::new(stream_of(atomics));
        let ta = ha.run();
        let mut hp = Harness::new(stream_of(plain));
        let tp = hp.run();
        assert!(
            ta as f64 > 2.5 * tp as f64,
            "atomic {ta} vs plain {tp} (expected >=2.5x)"
        );
    }

    #[test]
    fn wait_flag_spins_until_set() {
        let mut s = OpStream::new();
        s.push(Op {
            kind: OpKind::WaitFlag {
                instance: 0,
                flag: 3,
            },
            dep: 0,
            instrs: 2,
        });
        let mut h = Harness::new(s);
        // Set the flag after construction so the first poll spins.
        h.flags[0][3] = false;
        h.queue.push(0, Event::CoreWake(0));
        // Manually run a few steps, then set the flag.
        let mut t = 0;
        let mut set_done = false;
        let mut guard = 0;
        while let Some(ev) = h.queue.pop() {
            guard += 1;
            assert!(guard < 100_000);
            t = ev.time;
            if t > 500 && !set_done {
                h.flags[0][3] = true;
                set_done = true;
            }
            if let Event::CoreWake(_) = ev.event {
                h.wake_core(t);
                if h.core.done {
                    break;
                }
            }
        }
        assert!(h.core.done);
        assert!(h.core.stats.spin_instrs > 0, "should have spun");
        assert!(t > 500);
    }

    #[test]
    fn mmio_store_delivers_instruction() {
        let mut s = OpStream::new();
        for k in 0..3 {
            s.push(Op {
                kind: OpKind::MmioStore {
                    instance: 0,
                    seq: k / 3,
                },
                dep: 0,
                instrs: 1,
            });
        }
        let mut h = Harness::new(s);
        h.run();
        assert_eq!(h.mmio.len(), 3);
        assert!(h.mmio.iter().all(|d| d.instance == 0 && d.seq == 0));
        assert!(h.mmio[0].time >= 40);
    }

    #[test]
    fn streaming_loads_trigger_prefetcher() {
        // Sequential loads over one array with a stream tag: after warmup
        // the prefetcher should have issued work.
        let ops = (0..512u64).map(|i| Op::load(i * 64, 7, 1)).collect();
        let mut h = Harness::new(stream_of(ops));
        h.run();
        assert!(h.prefetcher.issued > 100, "issued={}", h.prefetcher.issued);
    }
}
