//! Abstract operations executed by the core model.
//!
//! Each [`Op`] models one *macro* operation (a load, a store, an RMW, a
//! block of arithmetic, a DX100 MMIO store, a scratchpad read, or a
//! synchronization wait) and carries the number of dynamic instructions it
//! accounts for — address calculation included — so the model reproduces
//! both timing and the paper's Figure 11a instruction counts.

/// The kind of one abstract core operation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OpKind {
    /// Demand load from `addr`; `stream` tags the access stream for the
    /// stride prefetcher (stand-in for the load PC).
    Load {
        /// Byte address.
        addr: u64,
        /// Access-stream tag for the stride prefetcher.
        stream: u32,
    },
    /// Store to `addr` (write-allocate).
    Store {
        /// Byte address.
        addr: u64,
        /// Access-stream tag for the stride prefetcher.
        stream: u32,
    },
    /// Read-modify-write on `addr`. When `atomic`, the op has fence
    /// semantics: it issues only at ROB head and blocks younger memory ops
    /// until done, plus a cacheline-lock penalty.
    Rmw {
        /// Byte address.
        addr: u64,
        /// Whether the RMW is atomic (fence semantics).
        atomic: bool,
    },
    /// Arithmetic block taking `cycles` of latency (dependent work).
    Compute {
        /// Latency in cycles.
        cycles: u32,
    },
    /// Streaming read of DX100 scratchpad data (cacheable, prefetched;
    /// fixed effective latency, no DRAM traffic).
    SpdLoad,
    /// Memory-mapped store carrying 1/3 of a DX100 instruction; on
    /// completion of the third store, instruction `seq` is delivered to
    /// DX100 instance `instance`.
    MmioStore {
        /// Target DX100 instance.
        instance: u16,
        /// Instruction sequence number.
        seq: u32,
    },
    /// Spin-wait until DX100 `instance` sets ready flag `flag` (tile ready
    /// bit). Models the library's `wait` API.
    WaitFlag {
        /// DX100 instance polled.
        instance: u16,
        /// Ready-flag index polled.
        flag: u32,
    },
}

/// One abstract operation plus its dependency and instruction weight.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Op {
    /// What the operation does.
    pub kind: OpKind,
    /// Data dependency: this op may issue only after the op `dep` positions
    /// *earlier in the same core's stream* has completed. 0 = none.
    pub dep: u32,
    /// Dynamic instructions this op accounts for (>=1 except pure markers).
    pub instrs: u16,
}

impl Op {
    /// A demand load on access stream `stream`, weighing `instrs`
    /// dynamic instructions.
    pub fn load(addr: u64, stream: u32, instrs: u16) -> Self {
        Op {
            kind: OpKind::Load { addr, stream },
            dep: 0,
            instrs,
        }
    }

    /// A store on access stream `stream`.
    pub fn store(addr: u64, stream: u32, instrs: u16) -> Self {
        Op {
            kind: OpKind::Store { addr, stream },
            dep: 0,
            instrs,
        }
    }

    /// A read-modify-write (optionally atomic, i.e. fence-like).
    pub fn rmw(addr: u64, atomic: bool, instrs: u16) -> Self {
        Op {
            kind: OpKind::Rmw { addr, atomic },
            dep: 0,
            instrs,
        }
    }

    /// An arithmetic block of `cycles` latency.
    pub fn compute(cycles: u32, instrs: u16) -> Self {
        Op {
            kind: OpKind::Compute { cycles },
            dep: 0,
            instrs,
        }
    }

    /// Attach a relative data dependency (see [`Op::dep`]).
    pub fn with_dep(mut self, dep: u32) -> Self {
        self.dep = dep;
        self
    }

    /// Whether the op occupies a load-queue slot.
    pub fn is_load(&self) -> bool {
        matches!(
            self.kind,
            OpKind::Load { .. } | OpKind::Rmw { .. } | OpKind::SpdLoad
        )
    }

    /// Whether the op occupies a store-queue slot.
    pub fn is_store(&self) -> bool {
        matches!(
            self.kind,
            OpKind::Store { .. } | OpKind::Rmw { .. } | OpKind::MmioStore { .. }
        )
    }

    /// Whether the op accesses the cache/DRAM hierarchy.
    pub fn is_mem(&self) -> bool {
        matches!(
            self.kind,
            OpKind::Load { .. } | OpKind::Store { .. } | OpKind::Rmw { .. }
        )
    }
}

/// A complete per-core operation stream.
#[derive(Clone, Debug, Default)]
pub struct OpStream {
    /// The operations, in program order.
    pub ops: Vec<Op>,
}

impl OpStream {
    /// An empty stream.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append an op; returns its absolute index.
    pub fn push(&mut self, op: Op) -> usize {
        self.ops.push(op);
        self.ops.len() - 1
    }

    /// Push an op depending on the op at absolute index `on` (must be
    /// earlier). Convenience over relative encoding.
    pub fn push_dep(&mut self, mut op: Op, on: usize) -> usize {
        let here = self.ops.len();
        assert!(on < here, "dependency must be earlier in the stream");
        op.dep = (here - on) as u32;
        self.push(op)
    }

    /// Number of ops in the stream.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Whether the stream has no ops.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total dynamic instruction count of the stream.
    pub fn total_instrs(&self) -> u64 {
        self.ops.iter().map(|o| o.instrs as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dep_encoding_is_relative() {
        let mut s = OpStream::new();
        let a = s.push(Op::load(0x100, 1, 2));
        let b = s.push_dep(Op::load(0x200, 2, 3), a);
        assert_eq!(s.ops[b].dep, 1);
        let _c = s.push(Op::compute(1, 1));
        let d = s.push_dep(Op::compute(5, 2), a);
        assert_eq!(s.ops[d].dep, 3);
    }

    #[test]
    #[should_panic]
    fn forward_dep_rejected() {
        let mut s = OpStream::new();
        s.push_dep(Op::compute(1, 1), 0); // depends on itself
    }

    #[test]
    fn instr_accounting() {
        let mut s = OpStream::new();
        s.push(Op::load(0, 0, 2));
        s.push(Op::compute(1, 3));
        s.push(Op::store(64, 0, 1));
        assert_eq!(s.total_instrs(), 6);
    }

    #[test]
    fn kind_classification() {
        assert!(Op::load(0, 0, 1).is_load());
        assert!(Op::rmw(0, true, 1).is_load());
        assert!(Op::rmw(0, true, 1).is_store());
        assert!(Op::store(0, 0, 1).is_store());
        assert!(!Op::compute(1, 1).is_mem());
    }
}
