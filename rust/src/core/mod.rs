//! Dependency-constrained out-of-order core model.
//!
//! This is the gem5-O3 stand-in. It executes an abstract per-core **op
//! stream** (produced by the mini-compiler from the workload IR) under the
//! structural limits the paper identifies as the baseline's MLP bottleneck
//! (§2.2): issue width, ROB capacity, LQ/SQ occupancy, cache MSHRs, the
//! dependency chain from index loads to indirect accesses, and fence
//! serialization for atomic RMW.

pub mod model;
pub mod ops;

pub use model::{
    CoreModel, CoreStats, LaneAction, LaneActionKind, LaneEnv, LineWaiters, MmioDelivery,
    PendingMem,
};
pub use ops::{Op, OpKind, OpStream};
