//! Engine integration: the threaded run matrix must be bit-identical to a
//! serial run of the same plan, and compile-once sharing must match the
//! legacy per-system compilation path.

use dx100::config::SystemConfig;
use dx100::coordinator::{Experiment, RunStats, SystemKind};
use dx100::engine::{execute, ExecOptions, RunPlan, ALL_SYSTEMS};
use dx100::workloads::{micro, nas, Scale, WorkloadSpec};

fn small_workloads() -> Vec<WorkloadSpec> {
    vec![
        micro::gather_full(4096, micro::IndexPattern::UniformRandom, 11),
        micro::rmw(2048, true, micro::IndexPattern::UniformRandom, 12),
        nas::cg(Scale::test()),
    ]
}

fn assert_identical(a: &RunStats, b: &RunStats) {
    assert_eq!(a.kind, b.kind);
    assert_eq!(a.workload, b.workload);
    let ctx = format!("{} on {:?}", a.workload, a.kind);
    assert_eq!(a.cycles, b.cycles, "cycles differ for {ctx}");
    assert_eq!(a.instrs, b.instrs, "instrs differ for {ctx}");
    assert_eq!(a.spin_instrs, b.spin_instrs, "spin differs for {ctx}");
    assert_eq!(a.dram_reads, b.dram_reads, "dram reads differ for {ctx}");
    assert_eq!(a.dram_writes, b.dram_writes, "dram writes differ for {ctx}");
    assert_eq!(a.dram_bytes, b.dram_bytes, "dram bytes differ for {ctx}");
    assert_eq!(a.events, b.events, "event counts differ for {ctx}");
    // Derived floats must match to the bit: same inputs, same math.
    assert_eq!(a.bw_util.to_bits(), b.bw_util.to_bits(), "bw {ctx}");
    assert_eq!(
        a.row_hit_rate.to_bits(),
        b.row_hit_rate.to_bits(),
        "rbh {ctx}"
    );
    assert_eq!(a.occupancy.to_bits(), b.occupancy.to_bits(), "occ {ctx}");
    assert_eq!(a.mpki.to_bits(), b.mpki.to_bits(), "mpki {ctx}");
}

#[test]
fn threaded_engine_is_deterministic() {
    let cfg = SystemConfig::table3();
    let ws = small_workloads();
    let plan = RunPlan::new(&cfg, &ws, &ALL_SYSTEMS);
    let serial = execute(&plan, &ExecOptions::new().threads(1));
    assert_eq!(serial.threads, 1);
    for threads in [2, 4] {
        let parallel = execute(&plan, &ExecOptions::new().threads(threads));
        assert!(parallel.threads >= 2, "expected a threaded run");
        assert_eq!(serial.workloads.len(), parallel.workloads.len());
        for (s, p) in serial.workloads.iter().zip(&parallel.workloads) {
            assert_eq!(s.workload, p.workload);
            assert_eq!(s.runs.len(), p.runs.len());
            for (a, b) in s.runs.iter().zip(&p.runs) {
                assert_identical(a, b);
            }
        }
    }
}

#[test]
fn compile_once_matches_per_system_compilation() {
    let cfg = SystemConfig::table3();
    let ws = vec![micro::gather_full(
        8192,
        micro::IndexPattern::UniformRandom,
        3,
    )];
    let plan = RunPlan::new(&cfg, &ws, &ALL_SYSTEMS);
    let shared = execute(&plan, &ExecOptions::new().threads(1));
    for kind in ALL_SYSTEMS {
        // The legacy path recompiles per system; stats must be identical.
        let direct = Experiment::new(kind, cfg.clone()).run(&ws[0], &ExecOptions::new());
        let via_engine = shared.workloads[0]
            .for_system(kind)
            .unwrap_or_else(|| panic!("missing {kind:?} run"));
        assert_identical(via_engine, &direct);
    }
}

#[test]
fn engine_results_are_plan_ordered() {
    let cfg = SystemConfig::table3();
    let ws = small_workloads();
    let plan = RunPlan::new(&cfg, &ws, &ALL_SYSTEMS);
    let r = execute(&plan, &ExecOptions::new().threads(4));
    assert_eq!(r.compiles, ws.len());
    let names: Vec<&str> = r.workloads.iter().map(|w| w.workload).collect();
    let expect: Vec<&str> = ws.iter().map(|w| w.program.name).collect();
    assert_eq!(names, expect);
    for wr in &r.workloads {
        let kinds: Vec<SystemKind> = wr.runs.iter().map(|r| r.kind).collect();
        assert_eq!(kinds, ALL_SYSTEMS.to_vec());
    }
}
