//! Determinism and cache-hygiene guarantees of simulated-time telemetry.
//!
//! The contract (`util::telemetry`, `docs/OBSERVABILITY.md`): collected
//! series are keyed on simulated cycles and bit-identical across the
//! whole `(DX100_THREADS, DX100_SHARDS)` matrix; the knob changes no
//! other statistic; it never enters a config or workload fingerprint;
//! and a cached replay can never surface stale telemetry — enabled runs
//! bypass cache reads and re-simulate.
//!
//! The tests flip the process-global telemetry state, so they serialize
//! on a file-local lock and always restore "off" before releasing it.
//! (Lib unit tests never enable telemetry for the same reason — this
//! integration binary is its own process.)

use dx100::config::SystemConfig;
use dx100::coordinator::{Experiment, SystemKind};
use dx100::engine::cache::{system_fingerprint, workload_fingerprint, ResultCache};
use dx100::engine::{execute_sweep, ExecOptions, SweepPlan, SweepPoint};
use dx100::util::telemetry;
use dx100::workloads::mix::{ArbPolicy, MixSpec};
use dx100::workloads::{micro, Registry, Scale};
use std::path::PathBuf;
use std::sync::Mutex;

static TELEMETRY_LOCK: Mutex<()> = Mutex::new(());

fn small_gather() -> dx100::workloads::WorkloadSpec {
    micro::gather_full(1 << 12, micro::IndexPattern::UniformRandom, 0x7E)
}

fn temp_cache(tag: &str) -> (ResultCache, PathBuf) {
    let dir = std::env::temp_dir().join(format!("dx100-telem-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    (ResultCache::at(&dir), dir)
}

/// Telemetry series are bit-identical across the full `(threads, shards)`
/// matrix on all three systems — the whole `RunStats` (telemetry
/// included, via `PartialEq`) must match the serial reference.
#[test]
fn telemetry_is_bit_identical_across_threads_and_shards() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let w = small_gather();
    let cfg = SystemConfig::table3();
    for kind in [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100] {
        let ex = Experiment::new(kind, cfg.clone());
        let reference = ex.run(&w, &ExecOptions::new().threads(1).shards(1).telemetry(true));
        let td = reference
            .telemetry
            .as_ref()
            .expect("telemetry-enabled run must collect");
        assert!(
            td.channels.iter().any(|c| !c.windows.is_empty()),
            "{kind:?}: no channel windows collected"
        );
        assert!(!td.samples.is_empty(), "{kind:?}: no system samples");
        for ch in &td.channels {
            let mut last = 0u64;
            for win in &ch.windows {
                assert!(win.t0 >= last && win.t1 >= win.t0, "{kind:?}: bad window");
                last = win.t1;
            }
        }
        if kind == SystemKind::Dx100 {
            assert!(!td.dx_latency.is_empty(), "DX100 run must record latencies");
            assert!(!td.dx_spans.is_empty(), "DX100 run must record spans");
        }
        for threads in [1usize, 2, 4] {
            for shards in [1usize, 2, 4] {
                let r = ex.run(
                    &w,
                    &ExecOptions::new()
                        .threads(threads)
                        .shards(shards)
                        .telemetry(true),
                );
                assert_eq!(
                    r, reference,
                    "{kind:?} telemetry diverged at threads={threads} shards={shards}"
                );
            }
        }
    }
    telemetry::set_enabled(false);
}

/// Multi-tenant mixes collect per-tenant progress series that are just as
/// deterministic across the shard fan-out.
#[test]
fn mix_telemetry_is_deterministic_and_per_tenant() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let reg = Registry::paper().with_synth();
    let mix = MixSpec::new()
        .tenant("uni-gather", 2)
        .tenant("zipf-gather", 2);
    let cfg = SystemConfig::table3();
    let mut reference = None;
    for shards in [1usize, 2, 4] {
        let opts = ExecOptions::new()
            .threads(1)
            .shards(shards)
            .no_cache()
            .telemetry(true);
        let r = dx100::engine::mix::run_mix(&mix, &reg, &cfg, Scale::test(), ArbPolicy::Fifo, &opts)
            .unwrap();
        let td = r
            .combined
            .telemetry
            .as_ref()
            .expect("mix run must collect telemetry");
        assert!(
            td.samples.iter().all(|s| s.tenant_instrs.len() == 2),
            "every sample must carry one progress entry per tenant"
        );
        // Per-tenant progress is cumulative within each tenant's slot.
        for t in 0..2 {
            let mut last = 0u64;
            for s in &td.samples {
                assert!(s.tenant_instrs[t] >= last, "tenant {t} progress regressed");
                last = s.tenant_instrs[t];
            }
        }
        match &reference {
            None => reference = Some(r.combined.clone()),
            Some(want) => assert_eq!(&r.combined, want, "mix diverged at shards={shards}"),
        }
    }
    telemetry::set_enabled(false);
}

/// The telemetry knob changes no statistic outside `RunStats::telemetry`:
/// an enabled run with the telemetry field cleared equals a disabled run
/// bit for bit.
#[test]
fn telemetry_knob_changes_no_other_field() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let w = small_gather();
    let cfg = SystemConfig::table3();
    for kind in [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100] {
        let ex = Experiment::new(kind, cfg.clone());
        let off = ex.run(&w, &ExecOptions::new().telemetry(false));
        assert!(off.telemetry.is_none(), "disabled run must not collect");
        let mut on = ex.run(&w, &ExecOptions::new().telemetry(true));
        assert!(on.telemetry.is_some());
        on.telemetry = None;
        assert_eq!(on, off, "{kind:?}: telemetry knob leaked into stats");
    }
    telemetry::set_enabled(false);
}

/// The knob stays out of every fingerprint: flipping it moves neither the
/// per-system config fingerprints nor the workload fingerprint.
#[test]
fn telemetry_is_absent_from_every_fingerprint() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = SystemConfig::table3();
    let w = small_gather();
    telemetry::set_enabled(false);
    let fps_off: Vec<u64> = [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100]
        .iter()
        .map(|&k| system_fingerprint(&cfg, k))
        .collect();
    let wfp_off = workload_fingerprint(&w);
    telemetry::set_enabled(true);
    let fps_on: Vec<u64> = [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100]
        .iter()
        .map(|&k| system_fingerprint(&cfg, k))
        .collect();
    assert_eq!(fps_off, fps_on, "config fingerprints must ignore the knob");
    assert_eq!(
        wfp_off,
        workload_fingerprint(&w),
        "workload fingerprint must ignore the knob"
    );
    telemetry::set_enabled(false);
}

/// Cached replays never surface stale telemetry: a telemetry-enabled
/// sweep over a warm cache bypasses the probe (0 hits), re-simulates, and
/// carries fresh series — while its non-telemetry stats still match the
/// cached run bit for bit, and the entries it stores remain usable by a
/// later telemetry-off sweep.
#[test]
fn warm_cache_is_bypassed_and_fresh_series_collected() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (cache, dir) = temp_cache("bypass");
    let ws = vec![small_gather()];
    let systems = [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100];
    let points = vec![SweepPoint::new("p", SystemConfig::table3())];
    let plan = SweepPlan::new(&points, &ws, &systems);

    let cold = execute_sweep(
        &plan,
        &ExecOptions::new()
            .threads(1)
            .cache(cache.clone())
            .telemetry(false),
    );
    assert_eq!(cold.cache_hits, 0);
    assert_eq!(cold.cache_misses, 3);

    // Telemetry on: the warm cache must NOT serve these cells.
    let fresh = execute_sweep(
        &plan,
        &ExecOptions::new()
            .threads(1)
            .cache(cache.clone())
            .telemetry(true),
    );
    assert_eq!(fresh.cache_hits, 0, "telemetry run must bypass cache reads");
    for (got, want) in fresh.points[0].workloads[0]
        .runs
        .iter()
        .zip(&cold.points[0].workloads[0].runs)
    {
        let td = got.telemetry.as_ref().expect("bypassed cell must collect");
        assert!(td.channels.iter().any(|c| !c.windows.is_empty()));
        let mut scrubbed = got.clone();
        scrubbed.telemetry = None;
        assert_eq!(&scrubbed, want, "bypassed re-simulation diverged");
    }

    // Telemetry off again: the same entries (written cold, and
    // re-written by the bypass run under the same keys) replay as hits
    // with no telemetry attached.
    let warm = execute_sweep(
        &plan,
        &ExecOptions::new()
            .threads(1)
            .cache(cache.clone())
            .telemetry(false),
    );
    assert_eq!(warm.cache_hits, 3, "knob must not split the cache key");
    for rs in &warm.points[0].workloads[0].runs {
        assert!(rs.telemetry.is_none(), "cached replay surfaced telemetry");
    }

    telemetry::set_enabled(false);
    let _ = std::fs::remove_dir_all(&dir);
}
