//! Compile-count hook: the engine must compile each workload exactly once
//! per suite invocation, no matter how many systems run it.
//!
//! This lives in its own test binary on purpose: the hook is a
//! process-wide counter, and any concurrently-running test that compiles a
//! workload would make exact assertions flaky.

use dx100::compiler::compile_invocations;
use dx100::config::SystemConfig;
use dx100::engine::{ExecOptions, Suite};
use dx100::workloads::micro;

#[test]
fn suite_compiles_each_workload_exactly_once() {
    let suite = Suite::new(SystemConfig::table3())
        .with_dmp()
        .workload(micro::gather_full(
            4096,
            micro::IndexPattern::UniformRandom,
            21,
        ))
        .workload(micro::scatter(2048, micro::IndexPattern::Streaming, 22));

    let before = compile_invocations();
    let result = suite.execute(&ExecOptions::new().threads(3));
    let after = compile_invocations();

    // 2 workloads x 3 systems = 6 runs, but only 2 compilations.
    assert_eq!(result.compiles, 2);
    assert_eq!(after - before, 2, "expected one compile per workload");
    assert_eq!(result.workloads.len(), 2);
    assert!(result.workloads.iter().all(|w| w.runs.len() == 3));

    // A second invocation compiles again: dedup is per suite execution,
    // not a process-global cache.
    let again = suite.execute(&ExecOptions::new().threads(1));
    assert_eq!(again.compiles, 2);
    assert_eq!(compile_invocations() - after, 2);
}
