//! Multi-tenant mix integration: the unified [`ExecOptions`] execution
//! path is bit-identical across its thread/shard knobs, co-scheduled
//! mixes are deterministic across the full `(DX100_THREADS,
//! DX100_SHARDS)` matrix, and mix solo baselines share persisted cache
//! entries with ordinary solo runs.

use dx100::config::SystemConfig;
use dx100::coordinator::SystemKind;
use dx100::engine::cache::ResultCache;
use dx100::engine::mix::{run_mix, MixResult};
use dx100::engine::{execute, execute_sweep, ExecOptions, RunPlan, SweepPlan, SweepPoint};
use dx100::workloads::mix::{ArbPolicy, MixSpec};
use dx100::workloads::{micro, Registry, Scale};
use std::path::PathBuf;

fn temp_cache(tag: &str) -> (ResultCache, PathBuf) {
    let dir = std::env::temp_dir().join(format!("dx100-mix-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    (ResultCache::at(&dir), dir)
}

/// The per-tenant config `run_mix` compiles solo baselines against: the
/// base config restricted to the tenant's core group with one DX100
/// context.
fn solo_cfg(cores: usize) -> SystemConfig {
    let mut cfg = SystemConfig::table3();
    cfg.core.num_cores = cores;
    cfg.dx100.instances = 1;
    cfg
}

/// The single execution path behind every public entry point is
/// bit-identical at every (threads, shards) setting — this is what the
/// deleted `run_sharded`/`execute_with`/`execute_sweep_sharded` variants
/// used to assert piecewise.
#[test]
fn exec_options_matrix_is_bit_identical() {
    let cfg = SystemConfig::table3();
    let w = [micro::gather_full(1 << 12, micro::IndexPattern::UniformRandom, 7)];
    let plan = RunPlan::new(&cfg, &w, &dx100::engine::BASE_AND_DX);
    let reference = execute(&plan, &ExecOptions::new().threads(1).shards(1));
    for threads in [1usize, 2, 4] {
        for shards in [1usize, 2, 4] {
            let r = execute(&plan, &ExecOptions::new().threads(threads).shards(shards));
            for (got, want) in r.workloads.iter().zip(&reference.workloads) {
                assert_eq!(
                    got.runs, want.runs,
                    "threads={threads} shards={shards} diverged on {}",
                    got.workload
                );
            }
        }
    }
}

fn assert_same_mix(a: &MixResult, b: &MixResult, tag: &str) {
    assert_eq!(a.combined, b.combined, "{tag}: combined stats diverged");
    assert_eq!(a.tenants.len(), b.tenants.len(), "{tag}");
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.solo, y.solo, "{tag}: {} solo diverged", x.workload);
        assert_eq!(x.mix, y.mix, "{tag}: {} slice diverged", x.workload);
        assert_eq!(
            x.slowdown.to_bits(),
            y.slowdown.to_bits(),
            "{tag}: {} slowdown diverged",
            x.workload
        );
    }
    assert_eq!(a.fairness.to_bits(), b.fairness.to_bits(), "{tag}");
}

/// Co-scheduled mixes are deterministic across the whole
/// `(threads, shards)` matrix, under every arbitration policy.
#[test]
fn mix_is_bit_identical_across_threads_and_shards() {
    let reg = Registry::paper().with_synth();
    let mix = MixSpec::new()
        .tenant("uni-gather", 2)
        .tenant("zipf-gather", 2);
    let cfg = SystemConfig::table3();
    let (cache, dir) = temp_cache("matrix");
    for policy in [ArbPolicy::Fifo, ArbPolicy::RoundRobin, ArbPolicy::OccupancyCap] {
        let mut reference: Option<MixResult> = None;
        for threads in [1usize, 2, 4] {
            for shards in [1usize, 2, 4] {
                let opts = ExecOptions::new()
                    .threads(threads)
                    .shards(shards)
                    .cache(cache.clone());
                let r = run_mix(&mix, &reg, &cfg, Scale::test(), policy, &opts).unwrap();
                match &reference {
                    None => reference = Some(r),
                    Some(want) => assert_same_mix(
                        &r,
                        want,
                        &format!("{} threads={threads} shards={shards}", policy.label()),
                    ),
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(dir);
}

/// A mix's solo baselines are the same cache cells as ordinary solo runs
/// of the same (config, workload): runs populate the cache for mixes and
/// vice versa.
#[test]
fn mix_solo_baselines_share_cache_with_ordinary_runs() {
    let reg = Registry::paper().with_synth();
    let (cache, dir) = temp_cache("reuse");
    // An ordinary solo run of uni-gather on the 2-core config...
    let points = [SweepPoint::new("", solo_cfg(2))];
    let workloads = [reg.build("uni-gather", Scale::test()).unwrap()];
    let systems = [SystemKind::Dx100];
    let plan = SweepPlan::new(&points, &workloads, &systems);
    let opts = ExecOptions::new().threads(1).cache(cache.clone());
    let solo = execute_sweep(&plan, &opts);
    assert_eq!((solo.cache_hits, solo.cache_misses), (0, 1));
    // ...is a cache hit for the mix's uni-gather baseline; only the
    // zipf-gather tenant still needs simulating.
    let mix = MixSpec::new()
        .tenant("uni-gather", 2)
        .tenant("zipf-gather", 2);
    let cfg = SystemConfig::table3();
    let r = run_mix(&mix, &reg, &cfg, Scale::test(), ArbPolicy::Fifo, &opts).unwrap();
    assert_eq!((r.solo_cache_hits, r.solo_cache_misses), (1, 1));
    // The cached baseline is the very result the ordinary run produced.
    let ordinary = &solo.points[0].workloads[0].runs[0];
    assert_eq!(&r.tenants[0].solo, ordinary);
    // A second mix under another policy replays both baselines.
    let r2 = run_mix(&mix, &reg, &cfg, Scale::test(), ArbPolicy::RoundRobin, &opts).unwrap();
    assert_eq!((r2.solo_cache_hits, r2.solo_cache_misses), (2, 0));
    let _ = std::fs::remove_dir_all(dir);
}

/// Phase offsets delay a tenant without perturbing determinism, and the
/// derived metrics stay in range.
#[test]
fn offsets_and_derived_metrics_are_sane() {
    let reg = Registry::paper().with_synth();
    let mix = MixSpec::new()
        .tenant("uni-gather", 2)
        .tenant_at("zipf-gather", 2, 5000);
    let cfg = SystemConfig::table3();
    let opts = ExecOptions::new().no_cache();
    let r = run_mix(&mix, &reg, &cfg, Scale::test(), ArbPolicy::OccupancyCap, &opts).unwrap();
    assert_eq!(r.tenants[1].offset, 5000);
    // The delayed tenant finishes after its offset, so the combined run
    // must span it.
    assert!(r.combined.cycles >= 5000, "{}", r.combined.cycles);
    assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12, "{}", r.fairness);
    for t in &r.tenants {
        assert!(t.slowdown > 0.0, "{}", t.workload);
        assert!(
            t.row_hit_interference.abs() <= 1.0,
            "{}: {}",
            t.workload,
            t.row_hit_interference
        );
    }
}
