//! Integration tests across the compiler pipeline: all 12 workloads
//! compile, produce equivalent functional results on both executors, and
//! emit structurally sensible DX100 programs.

use dx100::compiler::{analyze, compile, AccessClass};
use dx100::config::SystemConfig;
use dx100::dx100::isa::Opcode;
use dx100::workloads::{self, Scale};

#[test]
fn every_workload_functionally_equivalent() {
    let cfg = SystemConfig::table3();
    for w in workloads::all(Scale::test()) {
        let cw = compile(&w.program, &w.mem, &cfg)
            .unwrap_or_else(|e| panic!("{}: {e}", w.program.name));
        for arr in &w.program.arrays {
            for i in 0..arr.len as u64 {
                let b = cw.baseline.mem.read_word(arr.addr(i), arr.dtype.size());
                let d = cw.dx.mem.read_word(arr.addr(i), arr.dtype.size());
                if arr.dtype == dx100::dx100::isa::DType::F32 {
                    let (bf, df) = (f32::from_bits(b as u32), f32::from_bits(d as u32));
                    assert!(
                        (bf - df).abs() <= 1e-3 * bf.abs().max(1.0),
                        "{} {}[{i}]: {bf} vs {df}",
                        w.program.name,
                        arr.name
                    );
                } else {
                    assert_eq!(b, d, "{} {}[{i}]", w.program.name, arr.name);
                }
            }
        }
    }
}

#[test]
fn workload_isa_structure_matches_table1() {
    let cfg = SystemConfig::table3();
    let expect_rng = ["CG", "BFS", "PR", "BC", "GZI", "GZPI"];
    let expect_rmw = ["IS", "PR", "BC", "GZ", "GZP", "PRH"];
    for w in workloads::all(Scale::test()) {
        let cw = compile(&w.program, &w.mem, &cfg).unwrap();
        let ops: Vec<Opcode> = cw
            .dx
            .programs
            .iter()
            .flat_map(|p| p.instrs.iter().map(|t| t.inst.opcode))
            .collect();
        let name = w.program.name;
        if expect_rng.contains(&name) {
            assert!(ops.contains(&Opcode::Rng), "{name} should use RNG");
        }
        if expect_rmw.contains(&name) {
            assert!(ops.contains(&Opcode::Irmw), "{name} should use IRMW");
        }
        assert!(
            ops.iter().any(|o| matches!(
                o,
                Opcode::Ild | Opcode::Ist | Opcode::Irmw
            )),
            "{name} must perform indirect accesses"
        );
    }
}

#[test]
fn detection_classifies_workload_sites() {
    for w in workloads::all(Scale::test()) {
        let (a, legal) = analyze(&w.program);
        assert!(legal.is_ok(), "{}", w.program.name);
        let n_indirect = a
            .loads
            .iter()
            .filter(|l| matches!(l.class, AccessClass::Indirect { .. }))
            .count();
        // Every workload either has an indirect load site or an indirect
        // store/RMW (captured by max_indirection).
        assert!(
            n_indirect > 0 || a.max_indirection >= 1,
            "{} has no indirect site",
            w.program.name
        );
    }
}

#[test]
fn phase_count_scales_with_tile_size() {
    let w = workloads::nas::is(Scale::test());
    let mut small = SystemConfig::table3();
    small.dx100.tile_elems = 1024;
    let mut large = SystemConfig::table3();
    large.dx100.tile_elems = 16384;
    let cs = compile(&w.program, &w.mem, &small).unwrap();
    let cl = compile(&w.program, &w.mem, &large).unwrap();
    assert!(
        cs.dx.phases > cl.dx.phases,
        "1K tiles {} phases vs 16K tiles {}",
        cs.dx.phases,
        cl.dx.phases
    );
}

#[test]
fn dmp_hints_generated_for_indirect_workloads() {
    let w = workloads::nas::is(Scale::test());
    let cfg = SystemConfig::table3();
    let cw = compile(&w.program, &w.mem, &cfg).unwrap();
    let total: usize = cw.baseline.dmp_hints.iter().map(|h| h.len()).sum();
    assert!(total > 0, "IS should produce DMP hints");
}
