//! Integration tests: full workloads through all three systems, checking
//! the paper's qualitative results hold at test scale.

use dx100::config::SystemConfig;
use dx100::coordinator::{Experiment, SystemKind};
use dx100::engine::ExecOptions;
use dx100::metrics::compare_one;
use dx100::util::geomean;
use dx100::workloads::{self, micro, Scale};

fn cfg() -> SystemConfig {
    SystemConfig::table3()
}

#[test]
fn all_twelve_workloads_complete_on_all_systems() {
    for w in workloads::all(Scale::test()) {
        for kind in [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100] {
            let stats = Experiment::new(kind, cfg()).run(&w, &ExecOptions::new());
            assert!(
                stats.cycles > 0 && stats.instrs > 0,
                "{} on {kind:?}",
                w.program.name
            );
        }
    }
}

#[test]
fn suite_geomean_speedup_in_paper_ballpark() {
    // Paper: 2.6x. At reduced scale we accept a broad band but require a
    // clear win.
    let mut speedups = Vec::new();
    for w in workloads::all(Scale::test()) {
        let c = compare_one(&w, &cfg(), false);
        speedups.push(c.speedup());
    }
    let g = geomean(&speedups);
    assert!(g > 1.3, "geomean speedup too low: {g:.2} ({speedups:?})");
}

#[test]
fn bandwidth_and_rbh_improve_on_bandwidth_bound_workloads() {
    let w = workloads::nas::is(Scale::test());
    let c = compare_one(&w, &cfg(), false);
    assert!(
        c.bw_improvement() > 1.2,
        "IS bandwidth improvement {:.2}",
        c.bw_improvement()
    );
    assert!(
        c.rbh_improvement() > 1.1,
        "IS RBH improvement {:.2}",
        c.rbh_improvement()
    );
}

#[test]
fn instruction_reduction_holds() {
    let w = workloads::ume::gz(Scale::test());
    let c = compare_one(&w, &cfg(), false);
    assert!(
        c.instr_reduction() > 1.5,
        "GZ instruction reduction {:.2}",
        c.instr_reduction()
    );
}

#[test]
fn dx100_beats_dmp_on_random_gather() {
    let w = micro::gather_full(16384, micro::IndexPattern::UniformRandom, 99);
    let c = compare_one(&w, &cfg(), true);
    let vs_dmp = c.speedup_vs_dmp().unwrap();
    assert!(vs_dmp > 1.1, "DX100 vs DMP: {vs_dmp:.2}");
}

#[test]
fn allmiss_dx100_bandwidth_insensitive_to_order() {
    // Figure 8b/c headline: DX100 BW is flat across index orderings while
    // the baseline degrades.
    let d = cfg().dram;
    let worst = micro::gather_allmiss(
        &d,
        8,
        micro::AllMissOrder {
            rbh: 0.0,
            chi: false,
            bgi: false,
        },
    );
    let best = micro::gather_allmiss(
        &d,
        8,
        micro::AllMissOrder {
            rbh: 1.0,
            chi: true,
            bgi: true,
        },
    );
    let cw = compare_one(&worst, &cfg(), false);
    let cb = compare_one(&best, &cfg(), false);
    // Baseline degrades substantially from best to worst ordering.
    assert!(
        cb.baseline.bw_util > 1.5 * cw.baseline.bw_util,
        "baseline BW: best {:.2} vs worst {:.2}",
        cb.baseline.bw_util,
        cw.baseline.bw_util
    );
    // DX100 stays within a narrow band.
    let ratio = cb.dx100.bw_util / cw.dx100.bw_util.max(1e-9);
    assert!(
        (0.8..1.3).contains(&ratio),
        "DX100 BW should be order-insensitive: best {:.2} worst {:.2}",
        cb.dx100.bw_util,
        cw.dx100.bw_util
    );
    // And the worst-case speedup exceeds the best-case one.
    assert!(
        cw.speedup() > cb.speedup(),
        "worst-order speedup {:.2} should exceed best-order {:.2}",
        cw.speedup(),
        cb.speedup()
    );
}

#[test]
fn tile_size_monotonicity() {
    // Figure 13 shape: larger tiles help (1K -> 16K).
    let w = workloads::nas::is(Scale::test());
    let mut speedups = Vec::new();
    for tile in [1024usize, 16384] {
        let mut c = cfg();
        c.dx100.tile_elems = tile;
        let comp = compare_one(&w, &c, false);
        speedups.push(comp.speedup());
    }
    assert!(
        speedups[1] > speedups[0] * 0.95,
        "16K tile should not lose to 1K: {speedups:?}"
    );
}

#[test]
fn scaling_8core_holds_speedup() {
    // Figure 14 shape: the DX100 advantage survives 8 cores / 4 channels.
    let w = workloads::nas::is(Scale::test());
    let c4 = compare_one(&w, &SystemConfig::table3(), false);
    let c8 = compare_one(&w, &SystemConfig::table3_8core(), false);
    assert!(c8.speedup() > 1.2, "8-core speedup {:.2}", c8.speedup());
    assert!(
        c8.speedup() > 0.5 * c4.speedup(),
        "scaling collapse: 4c {:.2} vs 8c {:.2}",
        c4.speedup(),
        c8.speedup()
    );
}

#[test]
fn two_instances_run_and_complete() {
    let mut c = SystemConfig::table3_8core();
    c.dx100.instances = 2;
    let w = workloads::nas::is(Scale::test());
    let stats = Experiment::new(SystemKind::Dx100, c).run(&w, &ExecOptions::new());
    assert_eq!(stats.dx.len(), 2);
    assert!(stats.dx.iter().all(|d| d.instructions > 0));
}

#[test]
fn scatter_speedup_exceeds_gather_full() {
    // §6.1: scatter (single-core baseline) gains more than Gather-Full.
    let n = 1 << 14;
    let g = compare_one(
        &micro::gather_full(n, micro::IndexPattern::Streaming, 7),
        &cfg(),
        false,
    );
    let s = compare_one(
        &micro::scatter(n, micro::IndexPattern::Streaming, 8),
        &cfg(),
        false,
    );
    assert!(
        s.speedup() > g.speedup(),
        "scatter {:.2} should exceed gather-full {:.2}",
        s.speedup(),
        g.speedup()
    );
}

#[test]
fn rmw_atomic_speedup_hierarchy() {
    // §6.1: DX100 gains on RMW-Atomic >> RMW-NoAtom.
    let n = 1 << 14;
    let a = compare_one(
        &micro::rmw(n, true, micro::IndexPattern::Streaming, 9),
        &cfg(),
        false,
    );
    let p = compare_one(
        &micro::rmw(n, false, micro::IndexPattern::Streaming, 9),
        &cfg(),
        false,
    );
    assert!(
        a.speedup() > 2.0 * p.speedup(),
        "atomic {:.2} vs plain {:.2}",
        a.speedup(),
        p.speedup()
    );
}
