//! Compile-dedup hooks: across a sweep, the front end must compile each
//! workload exactly once, and DX100 specialization must run once per
//! (workload, compile-fingerprint) — config points that agree on the
//! compiler-relevant knobs (`dx100.*`, `core.num_cores`) share one
//! specialization.
//!
//! This lives in its own test binary on purpose: the hooks are
//! process-wide counters, and any concurrently-running test that compiles
//! a workload would make exact assertions flaky. Tests within this binary
//! serialize on [`HOOK_LOCK`] for the same reason.

use dx100::compiler::{compile_invocations, specialize_invocations};
use dx100::config::SystemConfig;
use dx100::engine::{ExecOptions, Sweep};
use dx100::workloads::micro;
use std::sync::Mutex;

static HOOK_LOCK: Mutex<()> = Mutex::new(());

#[test]
fn sweep_compiles_once_per_workload_and_specializes_per_fingerprint() {
    let _g = HOOK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Three config points: two agree on every compiler-relevant knob
    // (they differ only in the DRAM request buffer, which codegen never
    // reads) and one changes the tile size (compiler-relevant).
    let mut deep_buffer = SystemConfig::table3();
    deep_buffer.dram.request_buffer = 128;
    let mut small_tile = SystemConfig::table3();
    small_tile.dx100.tile_elems = 1024;

    let sweep = Sweep::new()
        .point("base", SystemConfig::table3())
        .point("buf128", deep_buffer)
        .point("tile1k", small_tile)
        .workload(micro::gather_full(
            4096,
            micro::IndexPattern::UniformRandom,
            31,
        ))
        .workload(micro::scatter(2048, micro::IndexPattern::Streaming, 32));

    let compiles_before = compile_invocations();
    let specializes_before = specialize_invocations();
    let r = sweep.execute(&ExecOptions::new().threads(3).no_cache());
    let compiles = compile_invocations() - compiles_before;
    let specializes = specialize_invocations() - specializes_before;

    // 3 points x 2 workloads x 2 systems = 12 cells...
    assert_eq!(r.cells(), 12);
    // ... but the hook sees ONE front-end compile per workload across all
    // config points,
    assert_eq!(compiles, 2, "expected one front-end compile per workload");
    assert_eq!(r.compiles, 2);
    // ... and one specialization per (workload, compile-fingerprint):
    // base+buf128 share, tile1k re-specializes.
    assert_eq!(
        specializes, 4,
        "expected base/buf128 to share a specialization"
    );
    assert_eq!(r.specializations, 4);

    // A second invocation compiles again: dedup is per sweep execution,
    // not a process-global cache (the *result* cache is what persists,
    // and it is explicitly disabled here).
    let r2 = sweep.execute(&ExecOptions::new().threads(1).no_cache());
    assert_eq!(r2.compiles, 2);
    assert_eq!(compile_invocations() - compiles_before, 4);
}

#[test]
fn dmp_points_split_front_end_compiles() {
    let _g = HOOK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // The front end bakes DMP hints into its interpretation, so two
    // points that differ in `dmp.*` cannot share one: the engine keys
    // front ends on (workload, dmp fingerprint).
    let mut warped = SystemConfig::table3();
    warped.dmp.depth = 4;
    let sweep = Sweep::new()
        .point("base", SystemConfig::table3())
        .point("dmp4", warped)
        .workload(micro::gather_full(
            4096,
            micro::IndexPattern::UniformRandom,
            33,
        ));
    let before = compile_invocations();
    let r = sweep.execute(&ExecOptions::new().threads(2).no_cache());
    let compiles = compile_invocations() - before;
    // 2 points x 1 workload x 2 systems (baseline + DX100) = 4 cells; the
    // baseline pair dedupes (its key ignores dmp.*), but each dmp
    // fingerprint gets its own front end for the DX100 cells.
    assert_eq!(r.cells(), 4);
    assert_eq!(compiles, 2, "expected one front end per dmp fingerprint");
    assert_eq!(r.compiles, 2);
    assert_eq!(r.deduped, 1, "baseline must dedupe across dmp.* points");
}
