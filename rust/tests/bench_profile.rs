//! End-to-end check of the harness's `profile` emission: a profiled run
//! must land a `profile` object in `BENCH_*.json` covering all five phase
//! regions of the staged quantum loop, and an unprofiled run must omit
//! the key entirely (the CI gate `bench_check --require-profile` builds
//! on exactly this contract).

use dx100::config::SystemConfig;
use dx100::coordinator::{Experiment, SystemKind};
use dx100::engine::ExecOptions;
use dx100::engine::harness::{Harness, Json};
use dx100::util::regions;
use dx100::workloads::micro;
use std::path::PathBuf;
use std::sync::Mutex;

/// The five regions `docs/CONCURRENCY.md` names; `bench_check` requires
/// the same set.
const PHASE_REGIONS: [&str; 5] = [
    "front_lanes",
    "dx100_lane",
    "shared_stage",
    "channel_crews",
    "merge",
];

/// Serializes the tests: they flip the process-global profiler state and
/// share the `DX100_BENCH_DIR` environment variable.
static PROFILE_LOCK: Mutex<()> = Mutex::new(());

fn bench_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dx100-bench-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::env::set_var("DX100_BENCH_DIR", &dir);
    dir
}

fn run_bench(name: &'static str) -> Json {
    let mut h = Harness::new(name, "profile emission smoke");
    let w = micro::gather_full(4096, micro::IndexPattern::UniformRandom, 31);
    // A DX100 run exercises every phase region, including the detached
    // accelerator lane.
    let rs = Experiment::new(SystemKind::Dx100, SystemConfig::table3()).run(&w, &ExecOptions::new());
    h.run("gather", &rs);
    h.finish();
    let path = std::env::var("DX100_BENCH_DIR").map(PathBuf::from).unwrap();
    let text = std::fs::read_to_string(path.join(format!("BENCH_{name}.json"))).unwrap();
    Json::parse(&text).unwrap()
}

#[test]
fn profiled_bench_json_carries_all_phase_regions() {
    let _g = PROFILE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = bench_dir("on");
    regions::set_enabled(true);
    let doc = run_bench("profile_on");
    regions::set_enabled(false);

    let profile = doc.get("profile").expect("profiled run must emit profile");
    for region in PHASE_REGIONS {
        let stat = profile
            .get(region)
            .unwrap_or_else(|| panic!("profile missing phase region {region:?}"));
        let secs = stat.get("seconds").and_then(Json::as_f64).unwrap();
        assert!(secs.is_finite() && secs >= 0.0, "{region}: bad seconds");
        let calls = stat.get("calls").and_then(Json::as_u64).unwrap();
        assert!(calls >= 1, "{region}: no entries recorded");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The region profiler and simulated-time telemetry compose in one run:
/// a bench with both knobs on emits BOTH objects in the same JSON, each
/// with its full contract intact (the `--profile --telemetry --trace`
/// CLI combination and the fig09 CI step rely on this).
#[test]
fn profile_and_telemetry_compose_in_one_run() {
    let _g = PROFILE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = bench_dir("both");
    regions::set_enabled(true);
    let mut h = Harness::new("profile_both", "profile + telemetry compose");
    let w = micro::gather_full(4096, micro::IndexPattern::UniformRandom, 31);
    let rs = Experiment::new(SystemKind::Dx100, SystemConfig::table3())
        .run(&w, &ExecOptions::new().telemetry(true));
    h.run("gather", &rs);
    h.finish();
    regions::set_enabled(false);
    dx100::util::telemetry::set_enabled(false);

    let path = std::env::var("DX100_BENCH_DIR").map(PathBuf::from).unwrap();
    let text = std::fs::read_to_string(path.join("BENCH_profile_both.json")).unwrap();
    let doc = Json::parse(&text).unwrap();
    let profile = doc.get("profile").expect("profiled run must emit profile");
    for region in PHASE_REGIONS {
        assert!(
            profile.get(region).is_some(),
            "compose run dropped phase region {region:?}"
        );
    }
    let telem = doc
        .get("telemetry")
        .and_then(|t| t.get("gather/dx100"))
        .expect("compose run must also emit telemetry");
    let channels = telem.get("channels").and_then(Json::as_array).unwrap();
    assert!(channels
        .iter()
        .any(|c| !c.get("windows").and_then(Json::as_array).unwrap().is_empty()));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unprofiled_bench_json_omits_profile() {
    let _g = PROFILE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = bench_dir("off");
    regions::set_enabled(false);
    let doc = run_bench("profile_off");
    assert!(
        doc.get("profile").is_none(),
        "unprofiled run must omit the profile key"
    );
    // The rest of the schema is unaffected either way.
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("profile_off"));
    assert!(doc.get("rows").and_then(Json::as_array).is_some());
    let _ = std::fs::remove_dir_all(&dir);
}
