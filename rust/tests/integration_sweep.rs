//! Sweep-engine integration: a threaded sweep must be bit-identical to a
//! serial run of the same plan, and a warm-cache rerun must replay every
//! cell bit-identically without executing anything.

use dx100::config::SystemConfig;
use dx100::coordinator::RunStats;
use dx100::engine::cache::ResultCache;
use dx100::engine::{execute_sweep, ExecOptions, SweepPlan, SweepPoint, SweepResult, BASE_AND_DX};
use dx100::workloads::{micro, nas, Scale, WorkloadSpec};
use std::path::PathBuf;

fn small_workloads() -> Vec<WorkloadSpec> {
    vec![
        micro::gather_full(4096, micro::IndexPattern::UniformRandom, 11),
        nas::cg(Scale::test()),
    ]
}

/// Two config points that differ in a compiler-relevant knob (tile size),
/// plus one that differs only in DRAM scheduling visibility.
fn points() -> Vec<SweepPoint> {
    let mut small_tile = SystemConfig::table3();
    small_tile.dx100.tile_elems = 1024;
    let mut deep_buffer = SystemConfig::table3();
    deep_buffer.dram.request_buffer = 128;
    vec![
        SweepPoint::new("base", SystemConfig::table3()),
        SweepPoint::new("tile1k", small_tile),
        SweepPoint::new("buf128", deep_buffer),
    ]
}

fn assert_identical(a: &RunStats, b: &RunStats) {
    assert_eq!(a.kind, b.kind);
    assert_eq!(a.workload, b.workload);
    let ctx = format!("{} on {:?}", a.workload, a.kind);
    assert_eq!(a.cycles, b.cycles, "cycles differ for {ctx}");
    assert_eq!(a.instrs, b.instrs, "instrs differ for {ctx}");
    assert_eq!(a.spin_instrs, b.spin_instrs, "spin differs for {ctx}");
    assert_eq!(a.dram_reads, b.dram_reads, "dram reads differ for {ctx}");
    assert_eq!(a.dram_writes, b.dram_writes, "dram writes differ for {ctx}");
    assert_eq!(a.dram_bytes, b.dram_bytes, "dram bytes differ for {ctx}");
    assert_eq!(a.events, b.events, "event counts differ for {ctx}");
    // Derived floats must match to the bit: same inputs, same math.
    assert_eq!(a.bw_util.to_bits(), b.bw_util.to_bits(), "bw {ctx}");
    assert_eq!(
        a.row_hit_rate.to_bits(),
        b.row_hit_rate.to_bits(),
        "rbh {ctx}"
    );
    assert_eq!(a.occupancy.to_bits(), b.occupancy.to_bits(), "occ {ctx}");
    assert_eq!(a.mpki.to_bits(), b.mpki.to_bits(), "mpki {ctx}");
    assert_eq!(a.dx.len(), b.dx.len(), "dx instance count differs {ctx}");
    for (x, y) in a.dx.iter().zip(&b.dx) {
        assert_eq!(x.instructions, y.instructions, "dx instrs {ctx}");
        assert_eq!(x.dram_reads, y.dram_reads, "dx reads {ctx}");
        assert_eq!(x.inserted_words, y.inserted_words, "dx words {ctx}");
        assert_eq!(x.indirect_accesses, y.indirect_accesses, "dx ind {ctx}");
        assert_eq!(x.finish_time, y.finish_time, "dx finish {ctx}");
    }
}

fn assert_same_results(a: &SweepResult, b: &SweepResult) {
    assert_eq!(a.points.len(), b.points.len());
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.label, pb.label);
        assert_eq!(pa.workloads.len(), pb.workloads.len());
        for (wa, wb) in pa.workloads.iter().zip(&pb.workloads) {
            assert_eq!(wa.workload, wb.workload);
            assert_eq!(wa.runs.len(), wb.runs.len());
            for (ra, rb) in wa.runs.iter().zip(&wb.runs) {
                assert_identical(ra, rb);
            }
        }
    }
}

fn temp_cache(tag: &str) -> (ResultCache, PathBuf) {
    let dir = std::env::temp_dir().join(format!("dx100-sweep-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    (ResultCache::at(&dir), dir)
}

#[test]
fn threaded_sweep_is_deterministic() {
    let points = points();
    let ws = small_workloads();
    let plan = SweepPlan::new(&points, &ws, &BASE_AND_DX);
    let serial = execute_sweep(&plan, &ExecOptions::new().threads(1).no_cache());
    assert_eq!(serial.threads, 1);
    assert_eq!(serial.cells(), 3 * 2 * 2);
    // One front end per workload, no matter how many config points.
    assert_eq!(serial.compiles, ws.len());
    // base and buf128 share a compile fingerprint; tile1k re-specializes.
    assert_eq!(serial.specializations, 2 * ws.len());
    for threads in [2, 4] {
        let parallel = execute_sweep(&plan, &ExecOptions::new().threads(threads).no_cache());
        assert!(parallel.threads >= 2, "expected a threaded run");
        assert_same_results(&serial, &parallel);
    }
}

#[test]
fn warm_cache_rerun_is_bit_identical_and_runs_nothing() {
    let points = points();
    let ws = small_workloads();
    let plan = SweepPlan::new(&points, &ws, &BASE_AND_DX);
    let (cache, dir) = temp_cache("warm");

    let cold = execute_sweep(&plan, &ExecOptions::new().threads(2).cache(cache.clone()));
    assert!(cold.cache_enabled);
    assert_eq!(cold.cache_hits, 0);
    assert_eq!(cold.cache_misses, cold.cells());
    assert!(cold.compiles > 0);

    let warm = execute_sweep(&plan, &ExecOptions::new().threads(2).cache(cache.clone()));
    assert!(warm.cache_enabled);
    assert_eq!(warm.cache_hits, warm.cells(), "all cells must hit");
    assert_eq!(warm.cache_misses, 0);
    // Nothing left to compile or specialize on a fully warm run.
    assert_eq!(warm.compiles, 0);
    assert_eq!(warm.specializations, 0);
    assert_same_results(&cold, &warm);

    // The cache also serves a serial run identically.
    let warm_serial = execute_sweep(&plan, &ExecOptions::new().threads(1).cache(cache.clone()));
    assert_eq!(warm_serial.cache_hits, warm_serial.cells());
    assert_same_results(&cold, &warm_serial);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cache_does_not_leak_across_configs_or_workloads() {
    // Populate a cache from one plan, then execute a *different* config
    // point and workload set against the same directory: everything must
    // miss (and still produce correct, plan-ordered results).
    let (cache, dir) = temp_cache("isolate");
    let base_points = vec![SweepPoint::new("base", SystemConfig::table3())];
    let ws = vec![micro::gather_full(
        2048,
        micro::IndexPattern::UniformRandom,
        7,
    )];
    let first = execute_sweep(
        &SweepPlan::new(&base_points, &ws, &BASE_AND_DX),
        &ExecOptions::new().threads(1).cache(cache.clone()),
    );
    assert_eq!(first.cache_hits, 0);

    // Same workload constructor, different size: different fingerprint.
    let ws2 = vec![micro::gather_full(
        4096,
        micro::IndexPattern::UniformRandom,
        7,
    )];
    let other = execute_sweep(
        &SweepPlan::new(&base_points, &ws2, &BASE_AND_DX),
        &ExecOptions::new().threads(1).cache(cache.clone()),
    );
    assert_eq!(other.cache_hits, 0, "different workload must not hit");

    // Same workload, different DRAM knob: different full fingerprint.
    let mut cfg = SystemConfig::table3();
    cfg.dram.request_buffer = 8;
    let alt_points = vec![SweepPoint::new("buf8", cfg)];
    let third = execute_sweep(
        &SweepPlan::new(&alt_points, &ws, &BASE_AND_DX),
        &ExecOptions::new().threads(1).cache(cache.clone()),
    );
    assert_eq!(third.cache_hits, 0, "different config must not hit");

    // And the original plan still hits everything.
    let again = execute_sweep(
        &SweepPlan::new(&base_points, &ws, &BASE_AND_DX),
        &ExecOptions::new().threads(1).cache(cache.clone()),
    );
    assert_eq!(again.cache_hits, again.cells());
    assert_same_results(&first, &again);

    let _ = std::fs::remove_dir_all(&dir);
}
