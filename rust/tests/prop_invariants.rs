//! Property tests over coordinator / compiler / accelerator invariants,
//! using the in-crate testkit (offline stand-in for proptest).

use dx100::compiler::ir::{Expr, Program, Stmt};
use dx100::compiler::{compile, interpret};
use dx100::config::SystemConfig;
use dx100::coordinator::{Experiment, SystemKind};
use dx100::engine::ExecOptions;
use dx100::dx100::isa::{DType, Instruction, Op, Opcode};
use dx100::dx100::mem_image::MemImage;
use dx100::testkit::{check, gen};
use dx100::util::Rng;

fn small_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::table3();
    cfg.dx100.tile_elems = 128;
    cfg
}

/// Random gather program: C[i] = A[B[i]] with random sizes/indices.
fn random_gather(rng: &mut Rng) -> (Program, MemImage) {
    let n = gen::size(rng, 600);
    let dlen = 64 + gen::size(rng, 960);
    let mut p = Program::new("prop-gather", n);
    let a = p.add_array("A", DType::F32, dlen);
    let b = p.add_array("B", DType::U32, n);
    let c = p.add_array("C", DType::F32, n);
    p.body = vec![Stmt::Store {
        arr: c,
        idx: Expr::Iv(0),
        val: Expr::load(a, Expr::load(b, Expr::Iv(0))),
    }];
    let mut mem = MemImage::new();
    for (i, v) in gen::f32s(rng, dlen).iter().enumerate() {
        mem.write_f32(p.arrays[a].addr(i as u64), *v);
    }
    for (i, v) in gen::indices(rng, n, dlen).iter().enumerate() {
        mem.write_u32(p.arrays[b].addr(i as u64), *v);
    }
    (p, mem)
}

/// Random conditional RMW program.
fn random_rmw(rng: &mut Rng) -> (Program, MemImage) {
    let n = gen::size(rng, 500);
    let dlen = 32 + gen::size(rng, 480);
    let mut p = Program::new("prop-rmw", n);
    let a = p.add_array("A", DType::F32, dlen);
    let b = p.add_array("B", DType::U32, n);
    let d = p.add_array("D", DType::U32, n);
    let v = p.add_array("V", DType::F32, n);
    p.set_reg(0, 1);
    let op = *rng.pick(&[Op::Add, Op::Min, Op::Max]);
    p.body = vec![Stmt::If {
        cond: Expr::bin(
            Op::Ge,
            Expr::load(d, Expr::Iv(0)),
            Expr::Reg(0, DType::U32),
        ),
        body: vec![Stmt::Rmw {
            arr: a,
            idx: Expr::load(b, Expr::Iv(0)),
            op,
            val: Expr::load(v, Expr::Iv(0)),
        }],
    }];
    let mut mem = MemImage::new();
    for (i, x) in gen::f32s(rng, dlen).iter().enumerate() {
        mem.write_f32(p.arrays[a].addr(i as u64), *x);
    }
    for (i, x) in gen::indices(rng, n, dlen).iter().enumerate() {
        mem.write_u32(p.arrays[b].addr(i as u64), *x);
    }
    for i in 0..n as u64 {
        mem.write_u32(p.arrays[d].addr(i), rng.below(2) as u32);
        mem.write_f32(p.arrays[v].addr(i), rng.f32());
    }
    (p, mem)
}

/// Random range-loop program (CG-shaped).
fn random_range(rng: &mut Rng) -> (Program, MemImage) {
    let rows = gen::size(rng, 200);
    let offs = gen::offsets(rng, rows, 6);
    let nnz = *offs.last().unwrap() as usize;
    let xlen = 32 + gen::size(rng, 224);
    let mut p = Program::new("prop-range", rows);
    let h = p.add_array("H", DType::U32, rows + 1);
    let vv = p.add_array("V", DType::F32, nnz.max(1));
    let c = p.add_array("C", DType::U32, nnz.max(1));
    let x = p.add_array("X", DType::F32, xlen);
    let y = p.add_array("Y", DType::F32, rows);
    p.atomic_rmw = false;
    p.body = vec![Stmt::RangeFor {
        lo: Expr::load(h, Expr::Iv(0)),
        hi: Expr::load(h, Expr::bin(Op::Add, Expr::Iv(0), Expr::cu32(1))),
        body: vec![Stmt::Rmw {
            arr: y,
            idx: Expr::Iv(0),
            op: Op::Add,
            val: Expr::bin(
                Op::Mul,
                Expr::load(vv, Expr::Iv(1)),
                Expr::load(x, Expr::load(c, Expr::Iv(1))),
            ),
        }],
    }];
    let mut mem = MemImage::new();
    mem.store_u32_slice(p.arrays[h].base, &offs);
    for j in 0..nnz as u64 {
        mem.write_f32(p.arrays[vv].addr(j), rng.f32());
        mem.write_u32(p.arrays[c].addr(j), rng.below(xlen as u64) as u32);
    }
    for i in 0..xlen as u64 {
        mem.write_f32(p.arrays[x].addr(i), rng.f32());
    }
    (p, mem)
}

fn assert_equiv(p: &Program, base: &MemImage, dx: &MemImage) {
    for arr in &p.arrays {
        for i in 0..arr.len as u64 {
            let b = base.read_word(arr.addr(i), arr.dtype.size());
            let d = dx.read_word(arr.addr(i), arr.dtype.size());
            if arr.dtype == DType::F32 {
                let (bf, df) = (f32::from_bits(b as u32), f32::from_bits(d as u32));
                assert!(
                    (bf - df).abs() <= 1e-3 * bf.abs().max(1.0),
                    "{}[{i}]: {bf} vs {df}",
                    arr.name
                );
            } else {
                assert_eq!(b, d, "{}[{i}]", arr.name);
            }
        }
    }
}

#[test]
fn prop_gather_codegen_equivalent_to_interp() {
    check("gather equivalence", 25, |rng| {
        let (p, mem) = random_gather(rng);
        let cw = compile(&p, &mem, &small_cfg()).unwrap();
        assert_equiv(&p, &cw.baseline.mem, &cw.dx.mem);
    });
}

#[test]
fn prop_rmw_codegen_equivalent_to_interp() {
    check("rmw equivalence", 25, |rng| {
        let (p, mem) = random_rmw(rng);
        let cw = compile(&p, &mem, &small_cfg()).unwrap();
        assert_equiv(&p, &cw.baseline.mem, &cw.dx.mem);
    });
}

#[test]
fn prop_range_codegen_equivalent_to_interp() {
    check("range equivalence", 15, |rng| {
        let (p, mem) = random_range(rng);
        let cw = compile(&p, &mem, &small_cfg()).unwrap();
        assert_equiv(&p, &cw.baseline.mem, &cw.dx.mem);
    });
}

#[test]
fn prop_interp_deterministic() {
    check("interp determinism", 10, |rng| {
        let (p, mem) = random_gather(rng);
        let a = interpret(&p, &mem, None);
        let b = interpret(&p, &mem, None);
        for arr in &p.arrays {
            for i in 0..arr.len as u64 {
                assert_eq!(
                    a.mem.read_u32(arr.addr(i)),
                    b.mem.read_u32(arr.addr(i))
                );
            }
        }
        assert_eq!(a.streams.len(), b.streams.len());
        for (x, y) in a.streams.iter().zip(&b.streams) {
            assert_eq!(x.ops, y.ops);
        }
    });
}

#[test]
fn prop_isa_roundtrip_random() {
    check("isa roundtrip", 200, |rng| {
        let opcode = Opcode::from_u8(rng.below(8) as u8).unwrap();
        let dtype = dx100::dx100::isa::DType::from_u8(rng.below(6) as u8).unwrap();
        let op = loop {
            let o = Op::from_u8(rng.below(15) as u8).unwrap();
            if opcode != Opcode::Irmw || o.rmw_legal() {
                break o;
            }
        };
        let inst = Instruction {
            opcode,
            dtype,
            op,
            base: rng.next_u64() & ((1 << 48) - 1),
            td: rng.below(33) as u8,
            td2: rng.below(33) as u8,
            ts1: rng.below(33) as u8,
            ts2: rng.below(33) as u8,
            tc: rng.below(33) as u8,
            rs1: rng.below(32) as u8,
            rs2: rng.below(32) as u8,
            rs3: rng.below(32) as u8,
        };
        assert_eq!(Instruction::decode(inst.encode()).unwrap(), inst);
    });
}

#[test]
fn prop_simulation_timing_sane() {
    // Timing invariants: DX100 never loses to baseline by more than the
    // dispatch overhead bound on random bandwidth-bound gathers, and all
    // systems produce nonzero finite results.
    check("timing sanity", 6, |rng| {
        let n = 2048 + gen::size(rng, 4096);
        let w = dx100::workloads::micro::gather_full(
            n,
            dx100::workloads::micro::IndexPattern::UniformRandom,
            rng.next_u64(),
        );
        let cfg = SystemConfig::table3();
        let base = Experiment::new(SystemKind::Baseline, cfg.clone()).run(&w, &ExecOptions::new());
        let dx = Experiment::new(SystemKind::Dx100, cfg).run(&w, &ExecOptions::new());
        assert!(base.cycles > 0 && dx.cycles > 0);
        assert!(base.bw_util <= 1.0 && dx.bw_util <= 1.0, "util must be <= peak");
        assert!(dx.row_hit_rate <= 1.0 && base.row_hit_rate <= 1.0);
        assert!(
            dx.cycles < 4 * base.cycles,
            "DX100 pathologically slow: {} vs {}",
            dx.cycles,
            base.cycles
        );
    });
}
