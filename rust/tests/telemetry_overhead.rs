//! Zero-overhead guard for simulated-time telemetry when it is off.
//!
//! `util::telemetry` gates hooks in the DRAM channels, the DX100 timing
//! model, and the coordinator's quantum loop, so the `DX100_TELEMETRY=0`
//! path must cost nothing measurable: components resolve the knob once
//! at construction into `None` state, and the gate itself is a single
//! relaxed atomic load. Like `tests/profiler_overhead.rs`, this pins the
//! strongest cheap proxy — **zero heap allocations** across many gate
//! checks while telemetry is disabled — with a per-thread counting
//! global allocator (const-initialized TLS cell, so the counter itself
//! never allocates; sibling test threads cannot bleed into the window).

use dx100::util::telemetry;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Mutex;

thread_local! {
    static LOCAL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// System allocator wrapper that counts this thread's allocations.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        LOCAL_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        LOCAL_ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn this_thread_allocs() -> u64 {
    LOCAL_ALLOCS.with(Cell::get)
}

/// Serializes the tests: they flip the process-global enable state.
static ENABLE_LOCK: Mutex<()> = Mutex::new(());

#[test]
fn disabled_telemetry_gate_allocates_nothing() {
    let _g = ENABLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Resolve the tri-state once (the first call may read the
    // environment, which allocates).
    telemetry::set_enabled(false);
    assert!(!telemetry::enabled());

    let before = this_thread_allocs();
    for _ in 0..100_000 {
        // The construction-time pattern every component uses: one gate
        // check deciding whether any state exists at all.
        if telemetry::enabled() {
            unreachable!("telemetry is off");
        }
    }
    let after = this_thread_allocs();
    assert_eq!(
        after - before,
        0,
        "disabled-telemetry gate must not allocate"
    );
}

#[test]
fn disabled_run_allocates_no_telemetry_state() {
    let _g = ENABLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    telemetry::set_enabled(false);
    // A full disabled run carries no telemetry: the `Option` state stays
    // `None` end to end. (Not a zero-allocation claim — the simulator
    // itself allocates — but the contract the gate exists for.)
    let w = dx100::workloads::micro::gather_full(
        1 << 10,
        dx100::workloads::micro::IndexPattern::Streaming,
        3,
    );
    let rs = dx100::coordinator::Experiment::new(
        dx100::coordinator::SystemKind::Dx100,
        dx100::config::SystemConfig::table3(),
    )
    .run(&w, &dx100::engine::ExecOptions::new().telemetry(false));
    assert!(rs.telemetry.is_none());
}
