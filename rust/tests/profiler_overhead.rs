//! Zero-overhead guard for the region profiler when it is off.
//!
//! `util::regions` instruments the simulator's hottest loops (front
//! lanes, DX100 lane, shared stage, channel crews, merge), so the
//! `DX100_PROFILE=0` path must cost nothing measurable. This test pins
//! the strongest cheap proxy available: **zero heap allocations** across
//! many begin/end and scope pairs while profiling is disabled. A counting
//! global allocator makes any accidental allocation (e.g. a thread-local
//! Vec growing, a String formatting) a hard failure rather than a silent
//! per-event tax.
//!
//! The test binary is its own process (integration test), so installing a
//! `#[global_allocator]` here cannot affect the library's other tests.
//! Allocations are counted **per thread** (const-initialized TLS cell, no
//! destructor, so the counter itself never allocates): the harness runs
//! tests on sibling threads whose incidental allocations must not bleed
//! into another test's measurement window.

use dx100::util::regions;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Mutex;

thread_local! {
    static LOCAL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// System allocator wrapper that counts this thread's allocations.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        LOCAL_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        LOCAL_ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn this_thread_allocs() -> u64 {
    LOCAL_ALLOCS.with(Cell::get)
}

/// Serializes the two tests: they flip the process-global enable state.
static ENABLE_LOCK: Mutex<()> = Mutex::new(());

#[test]
fn disabled_profiler_allocates_nothing_on_the_hot_path() {
    let _g = ENABLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Resolve the tri-state and warm every code path once (the first
    // enabled() call may read the environment, which allocates).
    regions::set_enabled(false);
    regions::reset();
    regions::begin("front_lanes");
    regions::end("front_lanes");
    drop(regions::scope("merge"));

    let before = this_thread_allocs();
    for _ in 0..100_000 {
        regions::begin("front_lanes");
        regions::end("front_lanes");
        let _s = regions::scope("shared_stage");
    }
    let after = this_thread_allocs();
    assert_eq!(
        after - before,
        0,
        "disabled-profiler hot path must not allocate"
    );
    // And it recorded nothing.
    assert!(regions::snapshot().is_empty());
}

#[test]
fn enabled_profiler_steady_state_does_not_allocate_per_scope() {
    let _g = ENABLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Not a zero-allocation claim overall (the totals vector and the
    // thread-local open-scope stack grow once), but steady-state entries
    // must not allocate per call: the per-exit cost is a clock read plus
    // a mutex'd counter update.
    regions::set_enabled(true);
    regions::reset();
    for _ in 0..64 {
        let _s = regions::scope("channel_crews");
    }
    let before = this_thread_allocs();
    for _ in 0..10_000 {
        let _s = regions::scope("channel_crews");
    }
    let after = this_thread_allocs();
    regions::set_enabled(false);
    assert_eq!(
        after - before,
        0,
        "steady-state profiling must not allocate per scope"
    );
    let snap = regions::snapshot();
    let crews = snap.iter().find(|r| r.name == "channel_crews").unwrap();
    assert_eq!(crews.calls, 64 + 10_000);
    regions::reset();
}
