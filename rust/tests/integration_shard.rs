//! Intra-run sharding: bit-identity and cache-key invariance.
//!
//! `DX100_SHARDS` is a fan-out hint: it splits one simulation's front-end
//! core lanes *and* its DRAM channel engines into crew jobs served by the
//! shared worker pool. The contract under test:
//!
//! * `RunStats` are **bit-identical** for every fan-out, on every system
//!   kind, for both multi-channel geometries (2-channel Table 3 and the
//!   4-channel §6.6 scale-up) — floats compared exactly, no epsilon.
//! * The front-end seam holds even when the core count does not divide
//!   the fan-out (uneven lane groups).
//! * Fan-outs above the core/channel counts clamp (and stay identical).
//! * A saturated pool (more fan-out than workers) degrades to inline
//!   execution of the same jobs: a `threads=2, shards=4` sweep equals a
//!   fully serial one.
//! * Sharding never enters a cache or dedup fingerprint: a sharded sweep
//!   replays cells cached by an unsharded sweep verbatim.

use dx100::config::SystemConfig;
use dx100::coordinator::{Experiment, SystemKind};
use dx100::engine::cache::ResultCache;
use dx100::engine::{execute_sweep, ExecOptions, SweepPlan, SweepPoint, ALL_SYSTEMS, BASE_AND_DX};
use dx100::workloads::{micro, nas, Scale, WorkloadSpec};
use std::path::PathBuf;

const ALL_KINDS: [SystemKind; 3] = ALL_SYSTEMS;

fn workloads() -> Vec<WorkloadSpec> {
    vec![
        micro::gather_full(8192, micro::IndexPattern::UniformRandom, 21),
        nas::cg(Scale::test()),
    ]
}

#[test]
fn sharded_stats_bit_identical_across_shard_counts() {
    // 4-channel geometry: shards 2 and 4 genuinely partition the channels.
    let cfg = SystemConfig::table3_8core();
    for w in &workloads() {
        for kind in ALL_KINDS {
            let ex = Experiment::new(kind, cfg.clone());
            let unsharded = ex.run(w, &ExecOptions::new().shards(1));
            assert!(unsharded.cycles > 0 && unsharded.events > 0);
            for shards in [2, 4] {
                let sharded = ex.run(w, &ExecOptions::new().shards(shards));
                assert_eq!(
                    unsharded, sharded,
                    "{kind:?}/{} diverged at {shards} shards",
                    w.program.name
                );
            }
        }
    }
}

#[test]
fn front_end_sharding_bit_identical_with_uneven_core_groups() {
    // 6 cores: fan-outs 2 and 4 both leave uneven lane groups (3+3 and
    // 2+2+1+1), exercising the front-end shard seam on every system.
    let mut cfg = SystemConfig::table3_8core();
    cfg.core.num_cores = 6;
    for w in &workloads() {
        for kind in ALL_KINDS {
            let ex = Experiment::new(kind, cfg.clone());
            let serial = ex.run(w, &ExecOptions::new().shards(1));
            assert!(serial.front_events > 0, "front end must process events");
            assert_eq!(
                serial.events,
                serial.front_events + serial.channel_events,
                "event accounting must split by phase"
            );
            // 3 leaves uneven groups on the 4-lane baseline front end
            // (2+1+1) and on the 6-lane DX100 one (2+2+2 channels-wise,
            // 2+2+1+1 at 4); every fan-out must be bit-identical.
            for shards in [2, 3, 4] {
                let sharded = ex.run(w, &ExecOptions::new().shards(shards));
                assert_eq!(
                    serial, sharded,
                    "{kind:?}/{} diverged at fan-out {shards} with 6 cores",
                    w.program.name
                );
            }
        }
    }
}

#[test]
fn pool_saturated_sweep_matches_serial() {
    // More fan-out than pool concurrency: a (threads=2, shards=4) sweep
    // must complete and equal the fully serial one bit for bit — shard
    // helpers are opportunistic, never load-bearing.
    let points = [SweepPoint::new("", SystemConfig::table3_8core())];
    let ws = workloads();
    let plan = SweepPlan::new(&points, &ws, &ALL_SYSTEMS);
    let serial = execute_sweep(&plan, &ExecOptions::new().threads(1).shards(1).no_cache());
    let saturated = execute_sweep(&plan, &ExecOptions::new().threads(2).shards(4).no_cache());
    assert_eq!(saturated.threads, 2);
    assert_eq!(saturated.shards, 4);
    for (pa, pb) in serial.points.iter().zip(&saturated.points) {
        for (wa, wb) in pa.workloads.iter().zip(&pb.workloads) {
            assert_eq!(wa.runs, wb.runs);
        }
    }
}

#[test]
fn shard_count_clamps_to_channel_count() {
    // Table 3 has 2 channels: 4 (and an absurd 64) shards clamp to 2 and
    // stay bit-identical.
    let cfg = SystemConfig::table3();
    let w = micro::gather_full(8192, micro::IndexPattern::UniformRandom, 22);
    for kind in [SystemKind::Baseline, SystemKind::Dx100] {
        let ex = Experiment::new(kind, cfg.clone());
        let unsharded = ex.run(&w, &ExecOptions::new().shards(1));
        for shards in [2, 4, 64] {
            assert_eq!(unsharded, ex.run(&w, &ExecOptions::new().shards(shards)), "{kind:?}@{shards}");
        }
    }
}

#[test]
fn stats_bit_identical_across_thread_shard_matrix() {
    // The full (DX100_THREADS, DX100_SHARDS) ∈ {1,2,4}² matrix on every
    // system kind: pool size and fan-out are pure execution hints, so all
    // nine sweeps must return the (1,1) run's RunStats bit for bit. This
    // covers the detached DX100 lane too — its deferred actions merge into
    // the shared stage identically whether the lane advances inline
    // (shards=1) or on a crew worker.
    let points = [SweepPoint::new("", SystemConfig::table3_8core())];
    let ws = [micro::gather_full(8192, micro::IndexPattern::UniformRandom, 25)];
    let plan = SweepPlan::new(&points, &ws, &ALL_SYSTEMS);
    let reference = execute_sweep(&plan, &ExecOptions::new().threads(1).shards(1).no_cache());
    for threads in [1, 2, 4] {
        for shards in [1, 2, 4] {
            if (threads, shards) == (1, 1) {
                continue;
            }
            let run = execute_sweep(&plan, &ExecOptions::new().threads(threads).shards(shards).no_cache());
            for (pa, pb) in reference.points.iter().zip(&run.points) {
                for (wa, wb) in pa.workloads.iter().zip(&pb.workloads) {
                    assert_eq!(
                        wa.runs, wb.runs,
                        "stats diverged at threads={threads}, shards={shards}"
                    );
                }
            }
        }
    }
}

fn temp_cache(tag: &str) -> (ResultCache, PathBuf) {
    let dir = std::env::temp_dir().join(format!("dx100-shard-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    (ResultCache::at(&dir), dir)
}

#[test]
fn sharded_sweep_hits_unsharded_cache_entries() {
    let (cache, dir) = temp_cache("xhit");
    let points = [SweepPoint::new("", SystemConfig::table3())];
    let ws = [micro::gather_full(4096, micro::IndexPattern::UniformRandom, 23)];
    let plan = SweepPlan::new(&points, &ws, &BASE_AND_DX);

    // Cold, unsharded: simulates and persists every cell.
    let cold = execute_sweep(&plan, &ExecOptions::new().threads(1).shards(1).cache(cache.clone()));
    assert_eq!(cold.shards, 1);
    assert_eq!(cold.cache_hits, 0);
    assert_eq!(cold.cache_misses, cold.cells());

    // Warm, sharded: the shard count must not perturb any cache key, so
    // every cell replays from the unsharded run's entries.
    let warm = execute_sweep(&plan, &ExecOptions::new().threads(2).shards(4).cache(cache.clone()));
    assert_eq!(warm.shards, 4);
    assert_eq!(warm.cache_hits, warm.cells());
    assert_eq!(warm.cache_misses, 0);

    // And the replayed stats are the unsharded ones, bit for bit.
    for (cp, wp) in cold.points.iter().zip(&warm.points) {
        for (cw, ww) in cp.workloads.iter().zip(&wp.workloads) {
            assert_eq!(cw.runs, ww.runs);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sharded_execution_matches_cacheless_sweep() {
    // No cache involved at all: a 4-sharded sweep equals a serial one.
    let points = [SweepPoint::new("", SystemConfig::table3_8core())];
    let ws = [micro::scatter(4096, micro::IndexPattern::Streaming, 24)];
    let plan = SweepPlan::new(&points, &ws, &BASE_AND_DX);
    let a = execute_sweep(&plan, &ExecOptions::new().threads(1).shards(1).no_cache());
    let b = execute_sweep(&plan, &ExecOptions::new().threads(2).shards(4).no_cache());
    for (pa, pb) in a.points.iter().zip(&b.points) {
        for (wa, wb) in pa.workloads.iter().zip(&pb.workloads) {
            assert_eq!(wa.runs, wb.runs);
        }
    }
}
