//! Intra-run channel sharding: bit-identity and cache-key invariance.
//!
//! `DX100_SHARDS` fans one simulation's DRAM channel engines out across
//! worker threads. The contract under test:
//!
//! * `RunStats` are **bit-identical** for every shard count, on every
//!   system kind, for both multi-channel geometries (2-channel Table 3 and
//!   the 4-channel §6.6 scale-up) — floats compared exactly, no epsilon.
//! * Shard counts above the channel count clamp (and stay identical).
//! * Sharding never enters a cache or dedup fingerprint: a sharded sweep
//!   replays cells cached by an unsharded sweep verbatim.

use dx100::config::SystemConfig;
use dx100::coordinator::{Experiment, SystemKind};
use dx100::engine::cache::ResultCache;
use dx100::engine::{execute_sweep_sharded, SweepPlan, SweepPoint, ALL_SYSTEMS, BASE_AND_DX};
use dx100::workloads::{micro, nas, Scale, WorkloadSpec};
use std::path::PathBuf;

const ALL_KINDS: [SystemKind; 3] = ALL_SYSTEMS;

fn workloads() -> Vec<WorkloadSpec> {
    vec![
        micro::gather_full(8192, micro::IndexPattern::UniformRandom, 21),
        nas::cg(Scale::test()),
    ]
}

#[test]
fn sharded_stats_bit_identical_across_shard_counts() {
    // 4-channel geometry: shards 2 and 4 genuinely partition the channels.
    let cfg = SystemConfig::table3_8core();
    for w in &workloads() {
        for kind in ALL_KINDS {
            let ex = Experiment::new(kind, cfg.clone());
            let unsharded = ex.run_sharded(w, 1);
            assert!(unsharded.cycles > 0 && unsharded.events > 0);
            for shards in [2, 4] {
                let sharded = ex.run_sharded(w, shards);
                assert_eq!(
                    unsharded, sharded,
                    "{kind:?}/{} diverged at {shards} shards",
                    w.program.name
                );
            }
        }
    }
}

#[test]
fn shard_count_clamps_to_channel_count() {
    // Table 3 has 2 channels: 4 (and an absurd 64) shards clamp to 2 and
    // stay bit-identical.
    let cfg = SystemConfig::table3();
    let w = micro::gather_full(8192, micro::IndexPattern::UniformRandom, 22);
    for kind in [SystemKind::Baseline, SystemKind::Dx100] {
        let ex = Experiment::new(kind, cfg.clone());
        let unsharded = ex.run_sharded(&w, 1);
        for shards in [2, 4, 64] {
            assert_eq!(unsharded, ex.run_sharded(&w, shards), "{kind:?}@{shards}");
        }
    }
}

fn temp_cache(tag: &str) -> (ResultCache, PathBuf) {
    let dir = std::env::temp_dir().join(format!("dx100-shard-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    (ResultCache::at(&dir), dir)
}

#[test]
fn sharded_sweep_hits_unsharded_cache_entries() {
    let (cache, dir) = temp_cache("xhit");
    let points = [SweepPoint::new("", SystemConfig::table3())];
    let ws = [micro::gather_full(4096, micro::IndexPattern::UniformRandom, 23)];
    let plan = SweepPlan::new(&points, &ws, &BASE_AND_DX);

    // Cold, unsharded: simulates and persists every cell.
    let cold = execute_sweep_sharded(&plan, 1, Some(&cache), 1);
    assert_eq!(cold.shards, 1);
    assert_eq!(cold.cache_hits, 0);
    assert_eq!(cold.cache_misses, cold.cells());

    // Warm, sharded: the shard count must not perturb any cache key, so
    // every cell replays from the unsharded run's entries.
    let warm = execute_sweep_sharded(&plan, 2, Some(&cache), 4);
    assert_eq!(warm.shards, 4);
    assert_eq!(warm.cache_hits, warm.cells());
    assert_eq!(warm.cache_misses, 0);

    // And the replayed stats are the unsharded ones, bit for bit.
    for (cp, wp) in cold.points.iter().zip(&warm.points) {
        for (cw, ww) in cp.workloads.iter().zip(&wp.workloads) {
            assert_eq!(cw.runs, ww.runs);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sharded_execution_matches_cacheless_sweep() {
    // No cache involved at all: a 4-sharded sweep equals a serial one.
    let points = [SweepPoint::new("", SystemConfig::table3_8core())];
    let ws = [micro::scatter(4096, micro::IndexPattern::Streaming, 24)];
    let plan = SweepPlan::new(&points, &ws, &BASE_AND_DX);
    let a = execute_sweep_sharded(&plan, 1, None, 1);
    let b = execute_sweep_sharded(&plan, 2, None, 4);
    for (pa, pb) in a.points.iter().zip(&b.points) {
        for (wa, wb) in pa.workloads.iter().zip(&pb.workloads) {
            assert_eq!(wa.runs, wb.runs);
        }
    }
}
