//! End-to-end check of the harness's `telemetry` emission and the
//! Chrome-trace exporter: a telemetry-enabled run must land a
//! `telemetry` object in `BENCH_*.json` keyed `workload/system` with
//! windowed channel series, a disabled run must omit the key entirely
//! (the CI gate `bench_check --require-telemetry` builds on exactly this
//! contract), and `chrome_trace` must lay the same data out as a
//! Perfetto-loadable timeline with monotone per-track timestamps.

use dx100::config::SystemConfig;
use dx100::coordinator::{Experiment, SystemKind};
use dx100::engine::harness::{chrome_trace, Harness, Json};
use dx100::engine::ExecOptions;
use dx100::util::telemetry;
use dx100::workloads::micro;
use std::path::PathBuf;
use std::sync::Mutex;

/// Serializes the tests: they flip the process-global telemetry state
/// and share the `DX100_BENCH_DIR` environment variable.
static TELEMETRY_LOCK: Mutex<()> = Mutex::new(());

fn bench_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dx100-btelem-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::env::set_var("DX100_BENCH_DIR", &dir);
    dir
}

fn run_bench(name: &'static str, on: bool) -> (Json, dx100::coordinator::RunStats) {
    let mut h = Harness::new(name, "telemetry emission smoke");
    let w = micro::gather_full(4096, micro::IndexPattern::UniformRandom, 31);
    let rs = Experiment::new(SystemKind::Dx100, SystemConfig::table3())
        .run(&w, &ExecOptions::new().telemetry(on));
    h.run("gather", &rs);
    h.finish();
    let path = std::env::var("DX100_BENCH_DIR").map(PathBuf::from).unwrap();
    let text = std::fs::read_to_string(path.join(format!("BENCH_{name}.json"))).unwrap();
    (Json::parse(&text).unwrap(), rs)
}

#[test]
fn telemetry_bench_json_carries_windowed_series() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = bench_dir("on");
    let (doc, _rs) = run_bench("telemetry_on", true);
    telemetry::set_enabled(false);

    let telem = doc
        .get("telemetry")
        .expect("telemetry-enabled run must emit the object");
    let run = telem
        .get("gather/dx100")
        .expect("entries are keyed workload/system");
    let channels = run.get("channels").and_then(Json::as_array).unwrap();
    assert!(!channels.is_empty());
    let mut windows = 0usize;
    for ch in channels {
        let ws = ch.get("windows").and_then(Json::as_array).unwrap();
        windows += ws.len();
        let mut last = 0u64;
        for w in ws {
            let t0 = w.get("t0").and_then(Json::as_u64).unwrap();
            let t1 = w.get("t1").and_then(Json::as_u64).unwrap();
            assert!(t0 >= last && t1 >= t0, "window series must be monotone");
            last = t1;
            let rhr = w.get("row_hit_rate").and_then(Json::as_f64).unwrap();
            assert!((0.0..=1.0).contains(&rhr));
        }
        let lat = ch.get("dram_latency").unwrap();
        let buckets = lat.get("buckets").and_then(Json::as_array).unwrap();
        assert_eq!(buckets.len(), telemetry::HIST_BUCKETS);
        let count = lat.get("count").and_then(Json::as_u64).unwrap();
        let total: u64 = buckets.iter().filter_map(Json::as_u64).sum();
        assert_eq!(total, count, "histogram buckets must sum to count");
    }
    assert!(windows > 0, "an active run must record channel windows");
    assert!(!run
        .get("samples")
        .and_then(Json::as_array)
        .unwrap()
        .is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn untelemetered_bench_json_omits_the_key() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = bench_dir("off");
    let (doc, rs) = run_bench("telemetry_off", false);
    assert!(rs.telemetry.is_none());
    assert!(
        doc.get("telemetry").is_none(),
        "disabled run must omit the telemetry key"
    );
    // The rest of the schema is unaffected either way.
    assert_eq!(
        doc.get("bench").and_then(Json::as_str),
        Some("telemetry_off")
    );
    assert!(doc.get("rows").and_then(Json::as_array).is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn chrome_trace_of_a_real_run_is_well_formed() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let w = micro::gather_full(4096, micro::IndexPattern::UniformRandom, 32);
    let rs = Experiment::new(SystemKind::Dx100, SystemConfig::table3())
        .run(&w, &ExecOptions::new().telemetry(true));
    telemetry::set_enabled(false);
    let td = rs.telemetry.as_deref().expect("run must collect");
    let doc = Json::parse(&chrome_trace(&[("gather/dx100", td)]).render()).unwrap();
    let evs = doc.get("traceEvents").and_then(Json::as_array).unwrap();
    assert!(evs.len() > 1, "timeline must carry events");
    // Track timestamps must never go backwards (what Perfetto relies on
    // per track, and what `bench_check --check-trace` verifies in CI).
    let mut last: std::collections::HashMap<(u64, u64), u64> = std::collections::HashMap::new();
    let mut slices = 0usize;
    for e in evs {
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        if ph == "M" {
            continue;
        }
        if ph == "X" {
            slices += 1;
            assert!(e.get("dur").and_then(Json::as_u64).is_some());
        }
        let pid = e.get("pid").and_then(Json::as_u64).unwrap();
        let tid = e.get("tid").and_then(Json::as_u64).unwrap_or(0);
        let ts = e.get("ts").and_then(Json::as_u64).unwrap();
        let prev = last.entry((pid, tid)).or_insert(0);
        assert!(ts >= *prev, "track ({pid},{tid}) went backwards");
        *prev = ts;
    }
    assert!(slices > 0, "busy windows / DX spans must emit slices");
}
