//! Checkpoint/resume bit-identity across the whole matrix.
//!
//! The contract (`engine::snapshot`, `docs/CHECKPOINT.md`): capturing
//! snapshots changes no statistic; a run resumed from any mid-run
//! snapshot reproduces the cold run's `RunStats` bit-for-bit at every
//! `(DX100_THREADS, DX100_SHARDS)` setting, on all three systems, for
//! solo runs and co-scheduled mixes, with telemetry and the profiler on
//! or off; and every malformed-snapshot path fails with a typed
//! [`SnapshotError`] naming the offending field — never a panic.
//!
//! Some tests flip the process-global telemetry/profiler state and all
//! of them compute snapshot identities from it, so every test serializes
//! on a file-local lock and the flipping tests restore "off" before
//! releasing it.

use dx100::config::SystemConfig;
use dx100::coordinator::{Experiment, SystemKind, Tenant};
use dx100::engine::snapshot::{read_info, SnapshotError, SnapshotInfo, FORMAT_VERSION};
use dx100::engine::ExecOptions;
use dx100::util::{regions, telemetry};
use dx100::workloads::mix::{ArbPolicy, MixSpec};
use dx100::workloads::{micro, Registry, Scale, WorkloadSpec};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

static SNAPSHOT_LOCK: Mutex<()> = Mutex::new(());

const SYSTEMS: [SystemKind; 3] = [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100];
const MATRIX: [usize; 3] = [1, 2, 4];

fn cfg() -> SystemConfig {
    SystemConfig::table3()
}

fn base_opts() -> ExecOptions {
    ExecOptions::new().no_cache()
}

fn workloads() -> [WorkloadSpec; 2] {
    [
        micro::gather_full(1 << 10, micro::IndexPattern::UniformRandom, 0xA1),
        micro::scatter(1 << 9, micro::IndexPattern::Streaming, 0xB2),
    ]
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dx100-snapres-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Every snapshot in `dir`, sorted by capture quantum.
fn snapshots_in(dir: &Path) -> Vec<(PathBuf, SnapshotInfo)> {
    let mut snaps: Vec<(PathBuf, SnapshotInfo)> = std::fs::read_dir(dir)
        .expect("snapshot dir exists")
        .filter_map(|e| {
            let p = e.ok()?.path();
            let info = read_info(&p).ok()?;
            Some((p, info))
        })
        .collect();
    snaps.sort_by_key(|(_, i)| i.quantum);
    snaps
}

fn resumable(snaps: &[(PathBuf, SnapshotInfo)]) -> Vec<(PathBuf, SnapshotInfo)> {
    snaps.iter().filter(|(_, i)| i.pending).cloned().collect()
}

/// A checkpoint interval yielding roughly a dozen snapshots for a run of
/// `cycles` simulated cycles (the quantum is the DRAM min completion
/// latency, as in the coordinator loop).
fn interval_for(cfg: &SystemConfig, cycles: u64) -> u64 {
    let quantum = cfg.dram.min_completion_latency().max(1);
    (cycles / quantum / 12).max(1)
}

/// Checkpointing perturbs nothing and resume reproduces the cold run
/// bit-for-bit: all three systems, two workloads, resume from the first,
/// middle, and last resumable snapshot, with the middle one re-driven at
/// every `(threads, shards)` point of the matrix.
#[test]
fn resume_is_bit_identical_across_systems_and_matrix() {
    let _g = SNAPSHOT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let c = cfg();
    for kind in SYSTEMS {
        let ex = Experiment::new(kind, c.clone());
        for w in &workloads() {
            let tag = format!("{}-{}", kind.label(), w.program.name);
            let plain = ex.try_run(w, &base_opts()).expect("plain run never fails");

            let dir = temp_dir(&tag);
            let every = interval_for(&c, plain.cycles);
            let ticked = ex
                .try_run(w, &base_opts().checkpoint_every(every).snapshot_dir(&dir))
                .expect("checkpointed run");
            assert_eq!(ticked, plain, "{tag}: checkpointing perturbed the run");

            let snaps = snapshots_in(&dir);
            assert!(snaps.len() >= 3, "{tag}: only {} snapshots captured", snaps.len());
            for (path, info) in &snaps {
                assert_eq!(info.version, FORMAT_VERSION, "{}", path.display());
                assert_eq!(info.system, kind.label(), "{}", path.display());
                assert!(!info.telemetry, "{}", path.display());
                assert_eq!(info.tenants.len(), 1, "{}", path.display());
                assert_eq!(info.tenants[0].name, w.program.name, "{}", path.display());
                assert!(info.body_len > 0, "{}", path.display());
            }
            for pair in snaps.windows(2) {
                assert!(
                    pair[0].1.quantum < pair[1].1.quantum,
                    "{tag}: quanta not strictly increasing"
                );
            }
            let res = resumable(&snaps);
            assert!(res.len() >= 2, "{tag}: only {} resumable snapshots", res.len());

            let (mid_path, _) = &res[res.len() / 2];
            for threads in MATRIX {
                for shards in MATRIX {
                    let r = ex
                        .try_run(
                            w,
                            &base_opts().threads(threads).shards(shards).resume_from(mid_path),
                        )
                        .expect("resume");
                    assert_eq!(
                        r, plain,
                        "{tag}: resume diverged at threads={threads} shards={shards}"
                    );
                }
            }
            for (path, info) in [&res[0], &res[res.len() - 1]] {
                let r = ex.try_run(w, &base_opts().resume_from(path)).expect("resume");
                assert_eq!(r, plain, "{tag}: resume from quantum {} diverged", info.quantum);
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

/// Snapshot-at-every-quantum == snapshot-once == no-snapshot, on one
/// small workload per system: the capture hook runs at every boundary
/// (including the final, non-resumable one) without touching a single
/// statistic, and a sparse schedule captures a strict subset.
#[test]
fn every_quantum_capture_equals_once_equals_none() {
    let _g = SNAPSHOT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let c = cfg();
    let w = micro::gather_full(1 << 8, micro::IndexPattern::Streaming, 0xC3);
    for kind in SYSTEMS {
        let ex = Experiment::new(kind, c.clone());
        let tag = format!("dense-{}", kind.label());
        let plain = ex.try_run(&w, &base_opts()).expect("plain run");

        let dense_dir = temp_dir(&tag);
        let dense = ex
            .try_run(&w, &base_opts().checkpoint_every(1).snapshot_dir(&dense_dir))
            .expect("dense checkpointing");
        assert_eq!(dense, plain, "{tag}: every-quantum capture perturbed the run");
        let snaps = snapshots_in(&dense_dir);
        assert!(snaps.len() >= 2, "{tag}: dense capture produced {} files", snaps.len());
        // One snapshot per quantum: the last one marks end-of-run.
        let (_, last) = snaps.last().expect("non-empty");
        assert!(!last.pending, "{tag}: final snapshot still claims pending work");
        let res = resumable(&snaps);
        assert_eq!(
            res.len(),
            snaps.len() - 1,
            "{tag}: exactly the final snapshot is non-resumable"
        );

        let once_dir = temp_dir(&format!("{tag}-once"));
        let (_, mid) = &res[res.len() / 2];
        let once = ex
            .try_run(
                &w,
                &base_opts().checkpoint_every(mid.quantum).snapshot_dir(&once_dir),
            )
            .expect("sparse checkpointing");
        assert_eq!(once, plain, "{tag}: sparse capture perturbed the run");
        let sparse = snapshots_in(&once_dir);
        assert!(
            !sparse.is_empty() && sparse.len() < snaps.len(),
            "{tag}: sparse schedule wrote {} of {} dense files",
            sparse.len(),
            snaps.len()
        );
        let _ = std::fs::remove_dir_all(&dense_dir);
        let _ = std::fs::remove_dir_all(&once_dir);
    }
}

/// The per-tenant config mixes compile against (`engine::mix` does the
/// same): the base restricted to the tenant's core group, one DX100.
fn tenant_cfg(base: &SystemConfig, cores: usize) -> SystemConfig {
    let mut cfg = base.clone();
    cfg.core.num_cores = cores;
    cfg.dx100.instances = 1;
    cfg
}

/// Assemble the relocated co-scheduled tenants of `mix` exactly as
/// `engine::mix::run_mix` does, so snapshot tests can drive
/// `try_run_mix` directly without re-running solo baselines.
fn build_tenants(mix: &MixSpec, reg: &Registry) -> (Experiment, Vec<Tenant>) {
    let base = cfg();
    let relocated = mix.build_relocated(reg, Scale::test()).expect("mix builds");
    let tenants: Vec<Tenant> = mix
        .tenants
        .iter()
        .zip(&relocated)
        .map(|(t, w)| {
            let tcfg = tenant_cfg(&base, t.cores);
            let cw = dx100::compiler::compile(&w.program, &w.mem, &tcfg).expect("tenant compiles");
            Tenant::at(&Arc::new(cw), w.warm_caches, t.offset)
        })
        .collect();
    let ex = Experiment::new(SystemKind::Dx100, tenant_cfg(&base, mix.total_cores()));
    (ex, tenants)
}

/// Co-scheduled mixes checkpoint and resume bit-identically too: the
/// combined stats and every per-tenant slice match the cold run across
/// the `(threads, shards)` matrix.
#[test]
fn mix_resume_is_bit_identical_across_matrix() {
    let _g = SNAPSHOT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let reg = Registry::paper().with_synth();
    let mix = MixSpec::new().tenant("uni-gather", 2).tenant("zipf-gather", 2);
    let (ex, tenants) = build_tenants(&mix, &reg);
    let plain = ex
        .try_run_mix("mix:snapres", &tenants, ArbPolicy::Fifo, &base_opts())
        .expect("plain mix run");

    let dir = temp_dir("mix");
    let every = interval_for(&ex.cfg, plain.stats.cycles);
    let ticked = ex
        .try_run_mix(
            "mix:snapres",
            &tenants,
            ArbPolicy::Fifo,
            &base_opts().checkpoint_every(every).snapshot_dir(&dir),
        )
        .expect("checkpointed mix run");
    assert_eq!(ticked, plain, "mix: checkpointing perturbed the run");

    let snaps = snapshots_in(&dir);
    let res = resumable(&snaps);
    assert!(res.len() >= 2, "mix: only {} resumable snapshots", res.len());
    for (_, info) in &snaps {
        assert_eq!(info.arb, ArbPolicy::Fifo.label());
        assert_eq!(info.tenants.len(), 2, "mix headers carry both tenants");
    }
    let (mid_path, _) = &res[res.len() / 2];
    for threads in MATRIX {
        for shards in MATRIX {
            let r = ex
                .try_run_mix(
                    "mix:snapres",
                    &tenants,
                    ArbPolicy::Fifo,
                    &base_opts().threads(threads).shards(shards).resume_from(mid_path),
                )
                .expect("mix resume");
            assert_eq!(r, plain, "mix resume diverged at threads={threads} shards={shards}");
        }
    }

    // A solo run cannot adopt a mix snapshot: tenant count mismatch.
    let solo = Experiment::new(SystemKind::Dx100, ex.cfg.clone());
    let w = workloads();
    let err = solo
        .try_run(&w[0], &base_opts().resume_from(mid_path))
        .expect_err("solo resume of a mix snapshot must fail");
    assert!(
        matches!(err, SnapshotError::FingerprintMismatch { field, .. }
            if field == "tenants" || field == "config"),
        "unexpected error: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Telemetry and the profiler ride through checkpoint/resume: the
/// resumed run reproduces the full `RunStats` — collected telemetry
/// series included, via `PartialEq` — and the telemetry knob is part of
/// the snapshot identity, so a mismatched resume is a typed error.
#[test]
fn telemetry_and_profile_survive_resume() {
    let _g = SNAPSHOT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let c = cfg();
    let w = micro::gather_full(1 << 10, micro::IndexPattern::UniformRandom, 0xD4);
    let ex = Experiment::new(SystemKind::Dx100, c.clone());
    let on = || base_opts().telemetry(true).profile(true);

    let plain = ex.try_run(&w, &on()).expect("telemetry run");
    assert!(plain.telemetry.is_some(), "telemetry-enabled run must collect");

    let dir = temp_dir("telem");
    let every = interval_for(&c, plain.cycles);
    let ticked = ex
        .try_run(&w, &on().checkpoint_every(every).snapshot_dir(&dir))
        .expect("checkpointed telemetry run");
    assert_eq!(ticked, plain, "telemetry: checkpointing perturbed the run");

    let snaps = snapshots_in(&dir);
    let res = resumable(&snaps);
    assert!(!res.is_empty(), "no resumable telemetry snapshots");
    for (_, info) in &snaps {
        assert!(info.telemetry, "headers must record the telemetry knob");
    }
    let (mid_path, _) = &res[res.len() / 2];
    for (threads, shards) in [(1, 1), (2, 4), (4, 2)] {
        let r = ex
            .try_run(
                &w,
                &on().threads(threads).shards(shards).resume_from(mid_path),
            )
            .expect("telemetry resume");
        assert_eq!(
            r, plain,
            "telemetry resume diverged at threads={threads} shards={shards}"
        );
    }

    // Resuming with telemetry off is an identity mismatch, not a panic.
    let err = ex
        .try_run(&w, &base_opts().telemetry(false).resume_from(mid_path))
        .expect_err("telemetry mismatch must fail");
    assert!(
        matches!(err, SnapshotError::FingerprintMismatch { field: "telemetry", .. }),
        "unexpected error: {err}"
    );
    assert!(err.to_string().contains("telemetry"), "error names the field: {err}");

    let _ = std::fs::remove_dir_all(&dir);
    telemetry::set_enabled(false);
    regions::set_enabled(false);
}

/// Capture one small run's snapshots and hand back the bytes of a
/// resumable one plus its path and the experiment that wrote it.
fn captured_snapshot(tag: &str) -> (Experiment, WorkloadSpec, PathBuf, Vec<u8>, PathBuf) {
    let w = micro::gather_full(1 << 8, micro::IndexPattern::Streaming, 0xE5);
    let ex = Experiment::new(SystemKind::Dx100, cfg());
    let dir = temp_dir(tag);
    ex.try_run(&w, &base_opts().checkpoint_every(1).snapshot_dir(&dir))
        .expect("capture run");
    let snaps = snapshots_in(&dir);
    let res = resumable(&snaps);
    let (path, _) = &res[res.len() / 2];
    let bytes = std::fs::read(path).expect("snapshot readable");
    (ex, w, path.clone(), bytes, dir)
}

/// Every malformed-snapshot path is a typed [`SnapshotError`] naming the
/// offending field: bad magic, unknown schema version, truncation,
/// corrupt body, identity mismatches, and resuming past the end of the
/// run. None of them panic.
#[test]
fn malformed_snapshots_fail_with_typed_errors() {
    let _g = SNAPSHOT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (ex, w, path, bytes, dir) = captured_snapshot("neg");
    let mangled = dir.join("mangled.bin");
    let run_from = |data: &[u8]| {
        std::fs::write(&mangled, data).expect("write mangled snapshot");
        ex.try_run(&w, &base_opts().resume_from(&mangled))
            .expect_err("mangled snapshot must be rejected")
    };

    // Bad magic.
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    let err = run_from(&bad);
    assert!(
        matches!(err, SnapshotError::Corrupt { field: "magic", .. }),
        "unexpected error: {err}"
    );
    assert!(err.to_string().contains("magic"), "error names the field: {err}");

    // Unknown schema version (bytes 8..12, little-endian u32).
    let mut bad = bytes.clone();
    bad[8..12].copy_from_slice(&99u32.to_le_bytes());
    let err = run_from(&bad);
    assert_eq!(
        err,
        SnapshotError::SchemaMismatch { found: 99, expected: FORMAT_VERSION }
    );
    assert!(err.to_string().contains("99"), "error names the version: {err}");

    // Truncated mid-header.
    let err = run_from(&bytes[..16]);
    assert!(matches!(err, SnapshotError::Truncated { .. }), "unexpected error: {err}");

    // Body shorter than the header claims.
    let err = run_from(&bytes[..bytes.len() - 7]);
    assert!(
        matches!(err, SnapshotError::Truncated { field: "body" }),
        "unexpected error: {err}"
    );

    // A corrupted body fails decode with a named field (clobber a run of
    // body bytes so some length prefix or tag goes out of range).
    let mut bad = bytes.clone();
    let n = bad.len();
    for b in &mut bad[n - 64..n - 32] {
        *b = 0xFF;
    }
    let err = run_from(&bad);
    assert!(
        matches!(
            err,
            SnapshotError::Corrupt { .. } | SnapshotError::Truncated { .. }
        ),
        "unexpected error: {err}"
    );

    // `read_info` rejects the same files without panicking.
    std::fs::write(&mangled, &bytes[..16]).expect("write truncated snapshot");
    assert!(matches!(
        read_info(&mangled),
        Err(SnapshotError::Truncated { .. })
    ));

    // Identity mismatches: wrong workload, wrong system, wrong config.
    let other = micro::scatter(1 << 8, micro::IndexPattern::Streaming, 0xE5);
    let err = ex
        .try_run(&other, &base_opts().resume_from(&path))
        .expect_err("workload mismatch must fail");
    assert!(
        matches!(err, SnapshotError::FingerprintMismatch { field: "workload", .. }),
        "unexpected error: {err}"
    );
    assert!(err.to_string().contains("workload"), "error names the field: {err}");

    let err = Experiment::new(SystemKind::Baseline, cfg())
        .try_run(&w, &base_opts().resume_from(&path))
        .expect_err("system mismatch must fail");
    assert!(
        matches!(err, SnapshotError::FingerprintMismatch { field: "system", .. }),
        "unexpected error: {err}"
    );

    let mut changed = cfg();
    changed.dx100.tiles *= 2;
    let err = Experiment::new(SystemKind::Dx100, changed)
        .try_run(&w, &base_opts().resume_from(&path))
        .expect_err("config mismatch must fail");
    assert!(
        matches!(err, SnapshotError::FingerprintMismatch { field: "config", .. }),
        "unexpected error: {err}"
    );

    // A nonexistent path is an I/O error, not a panic.
    let err = ex
        .try_run(&w, &base_opts().resume_from(dir.join("missing.bin")))
        .expect_err("missing snapshot must fail");
    assert!(matches!(err, SnapshotError::Io(_)), "unexpected error: {err}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Resuming the end-of-run snapshot is [`SnapshotError::ResumePastEnd`]:
/// the final capture records that no work remains.
#[test]
fn resume_past_end_is_rejected() {
    let _g = SNAPSHOT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let w = micro::gather_full(1 << 8, micro::IndexPattern::Streaming, 0xF6);
    let ex = Experiment::new(SystemKind::Dx100, cfg());
    let dir = temp_dir("pastend");
    ex.try_run(&w, &base_opts().checkpoint_every(1).snapshot_dir(&dir))
        .expect("capture run");
    let snaps = snapshots_in(&dir);
    let (last_path, last) = snaps.last().expect("snapshots captured");
    assert!(!last.pending, "final snapshot must be end-of-run");
    let err = ex
        .try_run(&w, &base_opts().resume_from(last_path))
        .expect_err("resume past end must fail");
    assert_eq!(err, SnapshotError::ResumePastEnd);
    assert!(
        err.to_string().contains("nothing to resume"),
        "error explains itself: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `RunStats` equality is the whole-struct bit-level contract the tests
/// above lean on — spot-check that a resumed run really exercises it by
/// comparing a few load-bearing fields explicitly too.
#[test]
fn resumed_stats_fields_match_cold_run() {
    let _g = SNAPSHOT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let w = micro::rmw(1 << 9, false, micro::IndexPattern::UniformRandom, 0x17);
    let ex = Experiment::new(SystemKind::Dx100, cfg());
    let plain = ex.try_run(&w, &base_opts()).expect("plain run");
    let dir = temp_dir("fields");
    let every = interval_for(&ex.cfg, plain.cycles);
    ex.try_run(&w, &base_opts().checkpoint_every(every).snapshot_dir(&dir))
        .expect("capture run");
    let res = resumable(&snapshots_in(&dir));
    assert!(!res.is_empty());
    let r = ex
        .try_run(&w, &base_opts().resume_from(&res[res.len() / 2].0))
        .expect("resume");
    assert_eq!(r.cycles, plain.cycles);
    assert_eq!(r.instrs, plain.instrs);
    assert_eq!(r.dram_reads, plain.dram_reads);
    assert_eq!(r.dram_writes, plain.dram_writes);
    assert_eq!(r.row_hit_rate.to_bits(), plain.row_hit_rate.to_bits());
    assert_eq!(r, plain);
    let _ = std::fs::remove_dir_all(&dir);
}
