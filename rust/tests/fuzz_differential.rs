//! Integration tests for the differential fuzzer (`engine::fuzz`): the
//! seeded batch must pass every oracle, replays must reproduce verdicts
//! bit-for-bit, and the output-snapshot hook the functional oracle rests
//! on must agree with a hand-run `interpret` reference.

use dx100::compiler::{compile, interpret};
use dx100::config::SystemConfig;
use dx100::coordinator::{snapshot_outputs, Experiment, RunInput, SystemKind};
use dx100::engine::fuzz::{case_seed, fuzz, replay, DEFAULT_SEED};
use dx100::engine::ExecOptions;
use dx100::workloads::micro;
use std::sync::Arc;

fn cfg() -> SystemConfig {
    SystemConfig::table3()
}

fn opts() -> ExecOptions {
    ExecOptions::new().no_cache()
}

/// The CI-default batch: a dozen seeded differential cases, zero oracle
/// violations. Every violation string is surfaced in the assert so a
/// regression names its seed directly.
#[test]
fn fuzz_smoke_batch_passes_all_oracles() {
    let r = fuzz(12, DEFAULT_SEED, false, false, &cfg(), &opts());
    assert_eq!(r.cases, 12);
    assert!(r.checks > 100, "oracles barely ran ({} checks)", r.checks);
    assert!(
        r.passed(),
        "fuzz failures:\n{}",
        r.failures
            .iter()
            .map(|f| format!("{} -> {:?} ({})", f.seed, f.violations, f.replay_line()))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// Mix mode: two sampled tenants under every arbitration policy, plus the
/// single-tenant-mix ≡ solo identity, for a few seeds.
#[test]
fn fuzz_mix_batch_passes_all_oracles() {
    let r = fuzz(3, DEFAULT_SEED, true, false, &cfg(), &opts());
    assert_eq!(r.cases, 3);
    assert!(
        r.passed(),
        "mix fuzz failures:\n{}",
        r.failures
            .iter()
            .map(|f| format!("{} -> {:?} ({})", f.seed, f.violations, f.replay_line()))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// A replayed seed reproduces its case verdict bit-for-bit: same check
/// count, same (empty) failure set, same verdict hash — and the same seed
/// replayed twice is identical.
#[test]
fn replay_reproduces_verdicts_bit_for_bit() {
    for case in [0usize, 3, 7] {
        let seed = case_seed(DEFAULT_SEED, case);
        let a = replay(seed, false, false, &cfg(), &opts());
        let b = replay(seed, false, false, &cfg(), &opts());
        assert_eq!(a.verdict_hash(), b.verdict_hash(), "seed {seed:#x}");
        assert_eq!(a.checks, b.checks, "seed {seed:#x}");
        assert!(a.passed(), "seed {seed:#x}: {:?}", a.failures);
    }
    // Replay is also invariant to the parallelism knobs: verdicts are a
    // pure function of (seed, config).
    let seed = case_seed(DEFAULT_SEED, 1);
    let narrow = ExecOptions::new().no_cache().threads(1).shards(1);
    let wide = ExecOptions::new().no_cache().threads(2).shards(4);
    let serial = replay(seed, false, false, &cfg(), &narrow);
    let fanned = replay(seed, false, false, &cfg(), &wide);
    assert_eq!(serial.verdict_hash(), fanned.verdict_hash());
}

/// The checkpoint/resume oracle layer (`--snapshot-check`): a pinned
/// batch of solo cases plus one mix case, every round trip bit-exact.
/// The layer adds checks on top of the plain batch, and its replay lines
/// carry the flag so CI failures reproduce with the same oracles.
#[test]
fn fuzz_snapshot_check_batch_passes() {
    let r = fuzz(3, DEFAULT_SEED, false, true, &cfg(), &opts());
    let plain = fuzz(3, DEFAULT_SEED, false, false, &cfg(), &opts());
    assert_eq!(r.cases, 3);
    assert!(
        r.checks > plain.checks,
        "snapshot layer added no checks ({} vs {})",
        r.checks,
        plain.checks
    );
    assert!(
        r.passed(),
        "snapshot-check failures:\n{}",
        r.failures
            .iter()
            .map(|f| format!("{} -> {:?} ({})", f.seed, f.violations, f.replay_line()))
            .collect::<Vec<_>>()
            .join("\n")
    );
    let m = fuzz(1, DEFAULT_SEED, true, true, &cfg(), &opts());
    assert!(
        m.passed(),
        "mix snapshot-check failures:\n{}",
        m.failures
            .iter()
            .map(|f| format!("{} -> {:?} ({})", f.seed, f.violations, f.replay_line()))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        m.failures.iter().all(|f| f.replay_line().contains("--snapshot-check")),
        "replay lines must carry the snapshot flag"
    );
}

/// Case seeds are a stable pure function of (base, index): distinct per
/// case and reproducible across processes (FNV, not `std::hash`).
#[test]
fn case_seeds_are_distinct_and_stable() {
    let seeds: Vec<u64> = (0..64).map(|c| case_seed(8, c)).collect();
    let mut uniq = seeds.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), seeds.len(), "case seeds collided");
    assert_eq!(seeds, (0..64).map(|c| case_seed(8, c)).collect::<Vec<_>>());
}

/// The functional-oracle foundation: `Experiment::output_snapshot` must
/// select, per system kind, exactly the memory image whose final output
/// values `interpret` predicts for a known-good workload.
#[test]
fn output_snapshot_hook_matches_interpret_reference() {
    let w = micro::gather_full(1 << 10, micro::IndexPattern::Streaming, 7);
    let c = cfg();
    let reference = interpret(&w.program, &w.mem, None);
    let want = snapshot_outputs(&w.program, &reference.mem);
    assert!(!want.is_empty(), "gather has an output array");
    assert!(want.iter().all(|s| !s.words.is_empty()));
    for kind in [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100] {
        let ex = Experiment::new(kind, c.clone());
        let cw = Arc::new(compile(&w.program, &w.mem, &ex.cfg).unwrap());
        let _ = ex.run(
            RunInput::Compiled {
                cw: &cw,
                warm: w.warm_caches,
            },
            &opts(),
        );
        let got = ex.output_snapshot(&cw, &w.program);
        assert_eq!(
            got,
            want,
            "{} snapshot diverges on a pure gather",
            kind.label()
        );
    }
}
