//! A/B guard for the per-system relevant-knob fingerprints.
//!
//! Baseline/DMP cache and dedup keys exclude the `dx100.*` knobs, and the
//! baseline key additionally excludes `dmp.*`
//! (`SystemConfig::fingerprint_sans_dx100` /
//! `fingerprint_sans_dx100_dmp`, selected per system by
//! `engine::cache::system_fingerprint`). Those exclusions are only safe
//! if no excluded knob is read on the keyed system's code path; by
//! inspection the sole `dx100.*` route is `LaneEnv`'s scratchpad/MMIO
//! latencies, which baseline/DMP instruction streams never consume, and
//! the sole `dmp.*` route is the compiled hint tables, which only the DMP
//! variant consults. These tests back the inspection at runtime: a config
//! pair differing in **every** excluded knob must produce bit-identical
//! `RunStats` on the keyed systems, and the sweep engine must dedupe /
//! cache-hit accordingly. If a future change makes a keyed path read an
//! excluded knob, the bit-identity assertions here fail before the
//! narrowed key can poison the cache.

use dx100::config::{Dx100Config, SystemConfig};
use dx100::prefetch::DmpConfig;
use dx100::coordinator::{Experiment, SystemKind};
use dx100::engine::cache::{system_fingerprint, ResultCache};
use dx100::engine::{execute_sweep, ExecOptions, SweepPlan, SweepPoint};
use dx100::workloads::micro;
use std::path::PathBuf;

/// `table3` with every `dx100.*` knob changed and nothing else.
///
/// Exhaustive destructuring (no `..`) on purpose: the narrowed cache key
/// drops the *whole* `dx100` section automatically, so a new knob that
/// this guard does not vary must be a compile error here, not a silently
/// untested exclusion.
fn dx_warped() -> SystemConfig {
    let mut cfg = SystemConfig::table3();
    let Dx100Config {
        instances,
        tile_elems,
        tiles,
        rowtab_rows,
        rowtab_cols,
        registers,
        request_table,
        alu_lanes,
        tlb_entries,
        fill_rate,
        writeback_rate,
        mmio_store_latency,
        spd_read_latency,
    } = &mut cfg.dx100;
    *instances = 2;
    *tile_elems = 1024;
    *tiles = 8;
    *rowtab_rows = 16;
    *rowtab_cols = 4;
    *registers = 64;
    *request_table = 32;
    *alu_lanes = 4;
    *tlb_entries = 64;
    *fill_rate = 2;
    *writeback_rate = 8;
    *mmio_store_latency = 999;
    *spd_read_latency = 77;
    cfg
}

/// `table3` with every `dmp.*` knob changed and nothing else. Same
/// exhaustive-destructure rule as [`dx_warped`]: a new prefetcher knob
/// must be varied here or fail to compile.
fn dmp_warped() -> SystemConfig {
    let mut cfg = SystemConfig::table3();
    let DmpConfig { depth, train_iters } = &mut cfg.dmp;
    *depth = 4;
    *train_iters = 8;
    cfg
}

fn temp_cache(tag: &str) -> (ResultCache, PathBuf) {
    let dir = std::env::temp_dir().join(format!("dx100-sysfp-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    (ResultCache::at(&dir), dir)
}

#[test]
fn cpu_fingerprints_collapse_across_dx_knobs_dx100s_must_not() {
    let base = SystemConfig::table3();
    let warp = dx_warped();
    for kind in [SystemKind::Baseline, SystemKind::Dmp] {
        assert_eq!(
            system_fingerprint(&base, kind),
            system_fingerprint(&warp, kind),
            "{kind:?} key must ignore dx100.* knobs"
        );
    }
    assert_ne!(
        system_fingerprint(&base, SystemKind::Dx100),
        system_fingerprint(&warp, SystemKind::Dx100),
        "DX100 key must track dx100.* knobs"
    );
}

#[test]
fn baseline_key_collapses_across_dmp_knobs_others_must_not() {
    let base = SystemConfig::table3();
    let warp = dmp_warped();
    assert_eq!(
        system_fingerprint(&base, SystemKind::Baseline),
        system_fingerprint(&warp, SystemKind::Baseline),
        "baseline key must ignore dmp.* knobs"
    );
    for kind in [SystemKind::Dmp, SystemKind::Dx100] {
        assert_ne!(
            system_fingerprint(&base, kind),
            system_fingerprint(&warp, kind),
            "{kind:?} key must track dmp.* knobs"
        );
    }
}

#[test]
fn ab_baseline_stats_bit_identical_across_dmp_knobs() {
    // Runtime half of the `dmp.*` exclusion: the baseline never consults
    // the hint tables, so warping the prefetcher knobs must leave its
    // stats bit-identical.
    let base = SystemConfig::table3();
    let warp = dmp_warped();
    let w = micro::gather_full(2048, micro::IndexPattern::UniformRandom, 0xAE);
    let a = Experiment::new(SystemKind::Baseline, base).run(&w, &ExecOptions::new());
    let b = Experiment::new(SystemKind::Baseline, warp).run(&w, &ExecOptions::new());
    assert!(a.bw_util.is_finite() && a.row_hit_rate.is_finite());
    assert!(a.occupancy.is_finite() && a.mpki.is_finite());
    assert_eq!(a, b, "baseline stats must not depend on dmp.* knobs");
}

#[test]
fn sweep_dedupes_baseline_across_dmp_only_points() {
    let points = vec![
        SweepPoint::new("base", SystemConfig::table3()),
        SweepPoint::new("warp", dmp_warped()),
    ];
    let ws = vec![micro::gather_full(
        2048,
        micro::IndexPattern::UniformRandom,
        0xAF,
    )];
    let systems = [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100];
    let plan = SweepPlan::new(&points, &ws, &systems);
    let r = execute_sweep(&plan, &ExecOptions::new().threads(2).no_cache());
    assert_eq!(r.cells(), 6);
    // Only the baseline of the warped point reuses the base point's run;
    // DMP and DX100 both track the prefetcher knobs.
    assert_eq!(r.deduped, 1);
    let a = &r.points[0].workloads[0].runs[0];
    let b = &r.points[1].workloads[0].runs[0];
    assert_eq!(a.kind, SystemKind::Baseline);
    assert_eq!(a, b, "deduped baseline runs must be shared");
}

#[test]
fn cache_serves_baseline_across_dmp_only_configs() {
    let (cache, dir) = temp_cache("dmp");
    let ws = vec![micro::gather_full(
        2048,
        micro::IndexPattern::UniformRandom,
        0xB0,
    )];
    let systems = [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100];
    let base_points = vec![SweepPoint::new("base", SystemConfig::table3())];
    let cold = execute_sweep(
        &SweepPlan::new(&base_points, &ws, &systems),
        &ExecOptions::new().threads(1).cache(cache.clone()),
    );
    assert_eq!(cold.cache_hits, 0);

    let warp_points = vec![SweepPoint::new("warp", dmp_warped())];
    let warm = execute_sweep(
        &SweepPlan::new(&warp_points, &ws, &systems),
        &ExecOptions::new().threads(1).cache(cache.clone()),
    );
    assert_eq!(warm.cache_hits, 1, "baseline must replay");
    assert_eq!(warm.cache_misses, 2, "DMP + DX100 must re-simulate");
    assert_eq!(
        &cold.points[0].workloads[0].runs[0],
        &warm.points[0].workloads[0].runs[0]
    );

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ab_baseline_and_dmp_stats_bit_identical_across_dx_knobs() {
    // The runtime half of the inspection: simulate one workload on both
    // configs and require *bit* identity (RunStats is PartialEq; the
    // derived floats compare by value, and these runs produce no NaNs —
    // asserted below so a NaN can never vacuously pass).
    let base = SystemConfig::table3();
    let warp = dx_warped();
    let w = micro::gather_full(2048, micro::IndexPattern::UniformRandom, 0xAB);
    for kind in [SystemKind::Baseline, SystemKind::Dmp] {
        let a = Experiment::new(kind, base.clone()).run(&w, &ExecOptions::new());
        let b = Experiment::new(kind, warp.clone()).run(&w, &ExecOptions::new());
        assert!(a.bw_util.is_finite() && a.row_hit_rate.is_finite());
        assert!(a.occupancy.is_finite() && a.mpki.is_finite());
        assert_eq!(a, b, "{kind:?} stats must not depend on dx100.* knobs");
    }
}

#[test]
fn sweep_dedupes_cpu_cells_across_dx_only_points() {
    let points = vec![
        SweepPoint::new("base", SystemConfig::table3()),
        SweepPoint::new("warp", dx_warped()),
    ];
    let ws = vec![micro::gather_full(
        2048,
        micro::IndexPattern::UniformRandom,
        0xAC,
    )];
    let systems = [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100];
    let plan = SweepPlan::new(&points, &ws, &systems);
    let r = execute_sweep(&plan, &ExecOptions::new().threads(2).no_cache());
    assert_eq!(r.cells(), 6);
    // Baseline and DMP of the warped point reuse the base point's runs;
    // only DX100 simulates twice.
    assert_eq!(r.deduped, 2);
    for si in [0, 1] {
        let a = &r.points[0].workloads[0].runs[si];
        let b = &r.points[1].workloads[0].runs[si];
        assert_eq!(a, b, "deduped {:?} runs must be shared", a.kind);
    }
    let dx_a = &r.points[0].workloads[0].runs[2];
    let dx_b = &r.points[1].workloads[0].runs[2];
    assert_eq!(dx_a.kind, SystemKind::Dx100);
    assert_eq!(dx_b.kind, SystemKind::Dx100);
}

#[test]
fn cache_serves_cpu_cells_across_dx_only_configs() {
    // Populate the cache at `base`; a sweep over the dx-warped config must
    // hit for baseline/DMP and miss only the DX100 cell.
    let (cache, dir) = temp_cache("ab");
    let ws = vec![micro::gather_full(
        2048,
        micro::IndexPattern::UniformRandom,
        0xAD,
    )];
    let systems = [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100];
    let base_points = vec![SweepPoint::new("base", SystemConfig::table3())];
    let cold = execute_sweep(
        &SweepPlan::new(&base_points, &ws, &systems),
        &ExecOptions::new().threads(1).cache(cache.clone()),
    );
    assert_eq!(cold.cache_hits, 0);

    let warp_points = vec![SweepPoint::new("warp", dx_warped())];
    let warm = execute_sweep(
        &SweepPlan::new(&warp_points, &ws, &systems),
        &ExecOptions::new().threads(1).cache(cache.clone()),
    );
    assert_eq!(warm.cache_hits, 2, "baseline + DMP must replay");
    assert_eq!(warm.cache_misses, 1, "DX100 must re-simulate");
    for si in [0, 1] {
        assert_eq!(
            &cold.points[0].workloads[0].runs[si],
            &warm.points[0].workloads[0].runs[si]
        );
    }

    let _ = std::fs::remove_dir_all(&dir);
}
