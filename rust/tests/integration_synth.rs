//! Scenario-synthesis integration: the full default grid lowers to
//! legal, in-bounds workloads; generation is seed-deterministic end to
//! end (bit-identical `RunStats`); and generated workloads are
//! first-class citizens of the persisted result cache.

use dx100::compiler::analyze;
use dx100::config::SystemConfig;
use dx100::coordinator::{Experiment, SystemKind};
use dx100::engine::cache::{workload_fingerprint, ResultCache};
use dx100::engine::{execute_sweep, ExecOptions, SweepPlan, SweepPoint, ALL_SYSTEMS};
use dx100::workloads::synth::{self, AccessShape, IndexDist, PatternSpec, ScenarioSpec};
use dx100::workloads::{Registry, Scale, WorkloadSpec};
use std::path::PathBuf;

/// A small scenario (fast to build and simulate in debug tests).
fn tiny(dist: IndexDist, shape: AccessShape, name: &str, seed: u64) -> ScenarioSpec {
    let pattern = PatternSpec::new(dist, seed).with_stream(1024).with_target(8192);
    ScenarioSpec::new(name, pattern, shape)
}

fn temp_cache(tag: &str) -> (ResultCache, PathBuf) {
    let dir = std::env::temp_dir().join(format!("dx100-synth-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    (ResultCache::at(&dir), dir)
}

#[test]
fn default_grid_lowers_legal_and_in_bounds() {
    let grid = synth::scenario_grid();
    assert!(grid.len() >= 24, "grid has only {} scenarios", grid.len());
    for spec in &grid {
        let w = spec.build(Scale::test());
        assert_eq!(w.suite, "synth");
        assert_eq!(w.program.name, spec.name);
        let (a, legal) = analyze(&w.program);
        assert!(legal.is_ok(), "{}: {:?}", spec.name, legal.err());
        assert!(a.max_indirection >= 1, "{} has no indirection", spec.name);
        // Debug builds validate inside WorkloadSpec::new already; keep the
        // explicit check so release-mode CI also exercises it.
        assert!(w.validate_bounds().is_ok(), "{}", spec.name);
    }
}

#[test]
fn fixed_seed_reproduces_bit_identical_runstats() {
    let spec = tiny(
        IndexDist::Zipf { theta: 0.8 },
        AccessShape::Gather,
        "det-gather",
        0xDE7,
    );
    // Two independent realizations of the same spec are the same workload
    // to the cache...
    let w1 = spec.build(Scale::test());
    let w2 = spec.build(Scale::test());
    assert_eq!(workload_fingerprint(&w1), workload_fingerprint(&w2));
    // ...and simulate bit-identically on every system.
    for kind in [SystemKind::Baseline, SystemKind::Dmp, SystemKind::Dx100] {
        let a = Experiment::new(kind, SystemConfig::table3()).run(&w1, &ExecOptions::new());
        let b = Experiment::new(kind, SystemConfig::table3()).run(&w2, &ExecOptions::new());
        assert_eq!(a, b, "{kind:?} differs across identical builds");
    }
    // A different seed is a different workload.
    let mut other = spec.clone();
    other.pattern.seed ^= 1;
    assert_ne!(
        workload_fingerprint(&other.build(Scale::test())),
        workload_fingerprint(&w1)
    );
}

#[test]
fn generated_workloads_replay_from_the_result_cache() {
    let (cache, dir) = temp_cache("replay");
    let ws: Vec<WorkloadSpec> = vec![
        tiny(IndexDist::Uniform, AccessShape::Gather, "c-gather", 1).build(Scale::test()),
        tiny(
            IndexDist::Hashed { buckets: 64 },
            AccessShape::Rmw {
                op: dx100::dx100::isa::Op::Add,
                atomic: true,
            },
            "c-rmw",
            2,
        )
        .build(Scale::test()),
    ];
    let points = vec![SweepPoint::new("", SystemConfig::table3())];
    let plan = SweepPlan::new(&points, &ws, &ALL_SYSTEMS);
    let cold = execute_sweep(&plan, &ExecOptions::new().threads(2).cache(cache.clone()));
    assert_eq!(cold.cache_hits, 0);
    assert_eq!(cold.cache_misses, 6);

    // Rebuild from the specs (fresh generation) and rerun: every cell
    // must replay bit-identically from the cache.
    let ws2: Vec<WorkloadSpec> = vec![
        tiny(IndexDist::Uniform, AccessShape::Gather, "c-gather", 1).build(Scale::test()),
        tiny(
            IndexDist::Hashed { buckets: 64 },
            AccessShape::Rmw {
                op: dx100::dx100::isa::Op::Add,
                atomic: true,
            },
            "c-rmw",
            2,
        )
        .build(Scale::test()),
    ];
    let plan2 = SweepPlan::new(&points, &ws2, &ALL_SYSTEMS);
    let warm = execute_sweep(&plan2, &ExecOptions::new().threads(2).cache(cache.clone()));
    assert_eq!(warm.cache_hits, warm.cells(), "all cells must hit");
    assert_eq!(warm.compiles, 0);
    for (a, b) in cold.points[0].workloads.iter().zip(&warm.points[0].workloads) {
        assert_eq!(a.workload, b.workload);
        for (ra, rb) in a.runs.iter().zip(&b.runs) {
            assert_eq!(ra, rb, "cached replay differs for {}", a.workload);
        }
    }

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn registry_sweeps_the_synth_family_through_the_engine() {
    // A tiny family sweep: the registry is the workload axis, the engine
    // the (config x system) axes. Uses two hand-registered scenarios so
    // the test stays fast; scenario_space runs the full grid.
    let mut reg = Registry::new();
    // A longer stream than `tiny` so the DX100-vs-baseline comparison at
    // the end has settled past startup effects.
    reg.register_scenario(ScenarioSpec::new(
        "fam-uni",
        PatternSpec::new(IndexDist::Uniform, 11).with_stream(8192).with_target(8192),
        AccessShape::Gather,
    ));
    reg.register_scenario(tiny(IndexDist::Chase, AccessShape::Gather, "fam-chase", 12));
    assert_eq!(reg.families(), vec!["synth"]);
    let ws = reg.build_family("synth", Scale::test());
    assert_eq!(ws.len(), 2);
    let points = vec![SweepPoint::new("", SystemConfig::table3())];
    let plan = SweepPlan::new(&points, &ws, &ALL_SYSTEMS);
    let r = execute_sweep(&plan, &ExecOptions::new().threads(2).no_cache());
    assert_eq!(r.cells(), 6);
    let names: Vec<&str> = r.points[0].workloads.iter().map(|w| w.workload).collect();
    assert_eq!(names, vec!["fam-uni", "fam-chase"]);
    // DX100 must beat the baseline on a random gather scenario (the
    // paper's core effect, reproduced on generated input).
    let uni = &r.points[0].workloads[0];
    let base = uni.for_system(SystemKind::Baseline).unwrap();
    let dx = uni.for_system(SystemKind::Dx100).unwrap();
    assert!(
        dx.cycles < base.cycles,
        "dx100 {} cycles vs baseline {}",
        dx.cycles,
        base.cycles
    );
}
