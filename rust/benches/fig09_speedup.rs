//! Figure 9: DX100 speedup over the 4-core baseline, 12 workloads.
//! Paper: 2.6x geomean. Expected shape: every workload > 1x, RMW-heavy and
//! bandwidth-bound kernels highest.
use dx100::config::SystemConfig;
use dx100::metrics::{bench_scale, geomean_of, run_suite};
use dx100::report;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let comps = run_suite(&SystemConfig::table3(), bench_scale(), false);
    println!("== Figure 9: DX100 speedup over baseline ==");
    print!("{}", report::speedup_table(&comps));
    println!(
        "paper: 2.6x geomean | measured: {:.2}x | bench wall time {:.1}s",
        geomean_of(&comps, |c| c.speedup()),
        t0.elapsed().as_secs_f64()
    );
}
