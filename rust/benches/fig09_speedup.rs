//! Figure 9: DX100 speedup over the 4-core baseline, 12 workloads.
//! Paper: 2.6x geomean. Expected shape: every workload > 1x, RMW-heavy and
//! bandwidth-bound kernels highest.
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::metrics::{comparisons_at, geomean_of, run_suite_sweep};
use dx100::report;

fn main() {
    let mut h = Harness::new("fig09", "Figure 9: DX100 speedup over baseline");
    let mut r = run_suite_sweep(&SystemConfig::table3(), h.scale(), false);
    h.sweep(&r);
    let comps = comparisons_at(r.points.remove(0));
    h.table(&report::speedup_table(&comps));
    h.comparisons(&comps);
    let g = geomean_of(&comps, |c| c.speedup());
    h.metric("geomean_speedup", g);
    h.paper(&format!("2.6x geomean | measured: {g:.2}x"));
    h.finish();
}
