//! Figure 13: performance sensitivity to the tile size (1K -> 32K).
//! Paper: speedup grows 1.7x -> 2.9x; coalescing improves 1.4x; +25% BW.
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::metrics::{geomean_of, run_suite};

fn main() {
    let mut h = Harness::new("fig13", "Figure 13: tile-size sensitivity");
    for tile in [1024usize, 4096, 16384, 32768] {
        let mut cfg = SystemConfig::table3();
        cfg.dx100.tile_elems = tile;
        let comps = run_suite(&cfg, h.scale(), false);
        let coalesce: f64 = comps
            .iter()
            .flat_map(|c| c.dx100.dx.iter())
            .map(|d| d.coalesce_factor())
            .sum::<f64>()
            / comps.len() as f64;
        let speedup = geomean_of(&comps, |c| c.speedup());
        let bw = 100.0 * comps.iter().map(|c| c.dx100.bw_util).sum::<f64>() / comps.len() as f64;
        h.line(&format!(
            "tile={tile:>6}: geomean speedup {speedup:.2}x | mean coalesce factor {coalesce:.2} | dx BW {bw:.1}%"
        ));
        h.comparisons_tagged(&comps, &format!("@tile{tile}"));
        h.metric(&format!("tile{tile}_geomean_speedup"), speedup);
        h.metric(&format!("tile{tile}_mean_coalesce"), coalesce);
        h.metric(&format!("tile{tile}_dx_bw_pct"), bw);
    }
    h.paper("speedup grows 1.7x -> 2.9x from 1K to 32K tiles");
    h.finish();
}
