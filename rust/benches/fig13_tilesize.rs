//! Figure 13: performance sensitivity to the tile size (1K -> 32K).
//! Paper: speedup grows 1.7x -> 2.9x; coalescing improves 1.4x; +25% BW.
use dx100::config::SystemConfig;
use dx100::metrics::{bench_scale, geomean_of, run_suite};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    println!("== Figure 13: tile-size sensitivity ==");
    for tile in [1024usize, 4096, 16384, 32768] {
        let mut cfg = SystemConfig::table3();
        cfg.dx100.tile_elems = tile;
        let comps = run_suite(&cfg, bench_scale(), false);
        let coalesce: f64 = comps
            .iter()
            .flat_map(|c| c.dx100.dx.iter())
            .map(|d| d.coalesce_factor())
            .sum::<f64>()
            / comps.len() as f64;
        println!(
            "tile={:>6}: geomean speedup {:.2}x | mean coalesce factor {:.2} | dx BW {:.1}%",
            tile,
            geomean_of(&comps, |c| c.speedup()),
            coalesce,
            100.0 * comps.iter().map(|c| c.dx100.bw_util).sum::<f64>() / comps.len() as f64,
        );
    }
    println!("bench wall time {:.1}s", t0.elapsed().as_secs_f64());
}
