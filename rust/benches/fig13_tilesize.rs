//! Figure 13: performance sensitivity to the tile size (1K -> 32K).
//! Paper: speedup grows 1.7x -> 2.9x; coalescing improves 1.4x; +25% BW.
//!
//! Runs as one SweepPlan: all four tile points share a single worker pool
//! (no per-point barrier), each workload's front end compiles exactly once
//! across the sweep (tile size only re-specializes the DX100 lowering),
//! and unchanged cells replay from the persisted result cache
//! (`DX100_CACHE=0` disables).
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::engine::{ExecOptions, Sweep};
use dx100::metrics::{comparisons_at, geomean_of};
use dx100::workloads;

const TILES: [usize; 4] = [1024, 4096, 16384, 32768];

fn main() {
    let mut h = Harness::new("fig13", "Figure 13: tile-size sensitivity");
    let mut sweep = Sweep::new().workloads(workloads::all(h.scale()));
    for tile in TILES {
        let mut cfg = SystemConfig::table3();
        cfg.dx100.tile_elems = tile;
        sweep = sweep.point(format!("tile{tile}"), cfg);
    }
    let r = sweep.execute(&ExecOptions::new());
    h.sweep(&r);
    for (point, tile) in r.points.into_iter().zip(TILES) {
        let comps = comparisons_at(point);
        let coalesce: f64 = comps
            .iter()
            .flat_map(|c| c.dx100.dx.iter())
            .map(|d| d.coalesce_factor())
            .sum::<f64>()
            / comps.len() as f64;
        let speedup = geomean_of(&comps, |c| c.speedup());
        let bw = 100.0 * comps.iter().map(|c| c.dx100.bw_util).sum::<f64>() / comps.len() as f64;
        h.line(&format!(
            "tile={tile:>6}: geomean speedup {speedup:.2}x | mean coalesce factor {coalesce:.2} | dx BW {bw:.1}%"
        ));
        h.comparisons_tagged(&comps, &format!("@tile{tile}"));
        h.metric(&format!("tile{tile}_geomean_speedup"), speedup);
        h.metric(&format!("tile{tile}_mean_coalesce"), coalesce);
        h.metric(&format!("tile{tile}_dx_bw_pct"), bw);
    }
    h.paper("speedup grows 1.7x -> 2.9x from 1K to 32K tiles");
    h.finish();
}
