//! Scenario space: the generated indirect-access grid (≥24 scenarios —
//! every index distribution × access shape, plus knob variants) × all
//! three systems, through the sweep engine.
//!
//! Where Figures 9-12 evaluate the 12 paper kernels, this bench probes
//! the *claim behind them*: reordering, coalescing, and interleaving help
//! across diverse access types and index distributions. Per scenario it
//! reports DX100 speedup over baseline and DMP plus the row-buffer hit
//! rates, and per distribution/shape family the geomean speedup.
//!
//! Generation is seed-deterministic, so rerunning with `DX100_CACHE=1`
//! replays every cell from the persisted result cache (CI asserts this).

use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::engine::{ExecOptions, Sweep};
use dx100::metrics::{comparisons_at, geomean_of, Comparison};
use dx100::workloads::Registry;

fn geomean_where(comps: &[Comparison], pred: impl Fn(&str) -> bool) -> f64 {
    let mut subset: Vec<Comparison> = Vec::new();
    for c in comps {
        if pred(c.workload) {
            subset.push(c.clone());
        }
    }
    geomean_of(&subset, |c| c.speedup())
}

fn main() {
    let mut h = Harness::new(
        "scenario_space",
        "Scenario space: generated indirect-access patterns",
    );
    let reg = Registry::synth();
    h.line(&format!("{} generated scenarios x baseline/DMP/DX100", reg.len()));
    let mut r = Sweep::new()
        .with_dmp()
        .point("", SystemConfig::table3())
        .workloads(reg.build_all(h.scale()))
        .execute(&ExecOptions::new());
    h.sweep(&r);
    let comps = comparisons_at(r.points.remove(0));
    h.line("scenario          speedup   vs DMP   rbh base->dx100");
    for c in &comps {
        let vs_dmp = c
            .speedup_vs_dmp()
            .map_or("    -".to_string(), |s| format!("{s:5.2}x"));
        h.line(&format!(
            "{:<16} {:6.2}x   {}   {:.2} -> {:.2}",
            c.workload,
            c.speedup(),
            vs_dmp,
            c.baseline.row_hit_rate,
            c.dx100.row_hit_rate,
        ));
        h.metric(&format!("{}_speedup", c.workload), c.speedup());
        h.metric(
            &format!("{}_base_row_hit_rate", c.workload),
            c.baseline.row_hit_rate,
        );
        h.metric(
            &format!("{}_dx_row_hit_rate", c.workload),
            c.dx100.row_hit_rate,
        );
    }
    h.comparisons(&comps);
    // Family geomeans cover the plain 5x5 grid only: the `+knob` variants
    // deliberately skew locality, which would make the `uni`/`zipf`
    // families incomparable with the others.
    for dist in ["uni", "zipf", "runs", "chase", "hash"] {
        let g = geomean_where(&comps, |w| w.starts_with(dist) && !w.contains('+'));
        h.line(&format!("geomean speedup, {dist:<5} scenarios: {g:.2}x"));
        h.metric(&format!("geomean_speedup_{dist}"), g);
    }
    for shape in ["gather", "scatter", "rmw", "cond", "2lvl"] {
        let g = geomean_where(&comps, |w| w.ends_with(shape));
        h.metric(&format!("geomean_speedup_{shape}"), g);
    }
    let g = geomean_of(&comps, |c| c.speedup());
    h.line(&format!("geomean speedup, all scenarios: {g:.2}x"));
    h.metric("geomean_speedup", g);
    h.paper("reordering/coalescing/interleaving generalize across access types (S5, Table 1)");
    h.finish();
}
