//! Figure 8b/c: All-Misses gather sweep over row-buffer-hit rate, channel
//! interleaving, and bank-group interleaving of the *input index order*.
//! Paper: DX100 82-85% BW regardless of order; baseline 65% best-case down
//! to ~26%; max speedup 9.9x at the worst ordering.
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::metrics::compare_one;
use dx100::workloads::micro::{self, AllMissOrder};

fn main() {
    let mut h = Harness::new("fig08_allmiss", "Figure 8b/c: All-Misses sweep");
    let cfg = SystemConfig::table3();
    let orders = [
        ("RBH0 CHI0 BGI0 (worst)", "worst", 0.0, false, false),
        ("RBH50 CHI0 BGI0", "rbh50", 0.5, false, false),
        ("RBH100 CHI0 BGI0", "rbh100", 1.0, false, false),
        ("RBH100 CHI1 BGI0", "rbh100chi", 1.0, true, false),
        ("RBH100 CHI1 BGI1 (best)", "best", 1.0, true, true),
    ];
    h.line(&format!(
        "{:<26} {:>9} {:>8} {:>8} {:>8} {:>8}",
        "index order", "speedup", "baseBW%", "dxBW%", "baseRBH%", "dxRBH%"
    ));
    for (name, tag, rbh, chi, bgi) in orders {
        let w = micro::gather_allmiss(&cfg.dram, 16, AllMissOrder { rbh, chi, bgi });
        let c = compare_one(&w, &cfg, false);
        h.line(&format!(
            "{:<26} {:>8.2}x {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%",
            name,
            c.speedup(),
            c.baseline.bw_util * 100.0,
            c.dx100.bw_util * 100.0,
            c.baseline.row_hit_rate * 100.0,
            c.dx100.row_hit_rate * 100.0
        ));
        h.comparisons_tagged(std::slice::from_ref(&c), &format!("@{tag}"));
        h.metric(&format!("{tag}_speedup"), c.speedup());
        h.metric(&format!("{tag}_dx_bw"), c.dx100.bw_util);
    }
    h.paper("DX100 82-85% BW at any order; baseline 65% -> ~26%; max speedup 9.9x");
    h.finish();
}
