//! Figure 8b/c: All-Misses gather sweep over row-buffer-hit rate, channel
//! interleaving, and bank-group interleaving of the *input index order*.
//! Paper: DX100 82-85% BW regardless of order; baseline 65% best-case down
//! to ~26%; max speedup 9.9x at the worst ordering.
use dx100::config::SystemConfig;
use dx100::metrics::compare_one;
use dx100::workloads::micro::{self, AllMissOrder};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let cfg = SystemConfig::table3();
    let orders = [
        ("RBH0 CHI0 BGI0 (worst)", 0.0, false, false),
        ("RBH50 CHI0 BGI0", 0.5, false, false),
        ("RBH100 CHI0 BGI0", 1.0, false, false),
        ("RBH100 CHI1 BGI0", 1.0, true, false),
        ("RBH100 CHI1 BGI1 (best)", 1.0, true, true),
    ];
    println!("== Figure 8b/c: All-Misses sweep ==");
    println!(
        "{:<26} {:>9} {:>8} {:>8} {:>8} {:>8}",
        "index order", "speedup", "baseBW%", "dxBW%", "baseRBH%", "dxRBH%"
    );
    for (name, rbh, chi, bgi) in orders {
        let w = micro::gather_allmiss(&cfg.dram, 16, AllMissOrder { rbh, chi, bgi });
        let c = compare_one(&w, &cfg, false);
        println!(
            "{:<26} {:>8.2}x {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%",
            name,
            c.speedup(),
            c.baseline.bw_util * 100.0,
            c.dx100.bw_util * 100.0,
            c.baseline.row_hit_rate * 100.0,
            c.dx100.row_hit_rate * 100.0
        );
    }
    println!("bench wall time {:.1}s", t0.elapsed().as_secs_f64());
}
