//! Figure 10: (a) bandwidth utilization, (b) row-buffer hit rate,
//! (c) request-buffer occupancy — baseline vs DX100.
//! Paper: 3.9x BW, 2.7x RBH, 12.1x occupancy on average.
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::metrics::{comparisons_at, geomean_of, run_suite_sweep};
use dx100::report;

fn main() {
    let mut h = Harness::new("fig10", "Figure 10: bandwidth / RBH / occupancy");
    let mut r = run_suite_sweep(&SystemConfig::table3(), h.scale(), false);
    h.sweep(&r);
    let comps = comparisons_at(r.points.remove(0));
    h.table(&report::bandwidth_table(&comps));
    h.comparisons(&comps);
    let bw = geomean_of(&comps, |c| c.bw_improvement());
    let rbh = geomean_of(&comps, |c| c.rbh_improvement());
    let occ = geomean_of(&comps, |c| c.occupancy_improvement());
    h.metric("geomean_bw_improvement", bw);
    h.metric("geomean_rbh_improvement", rbh);
    h.metric("geomean_occupancy_improvement", occ);
    h.paper(&format!(
        "BW 3.9x, RBH 2.7x, occupancy 12.1x | measured: BW {bw:.2}x | RBH {rbh:.2}x | occ {occ:.2}x"
    ));
    h.finish();
}
