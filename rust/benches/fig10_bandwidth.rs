//! Figure 10: (a) bandwidth utilization, (b) row-buffer hit rate,
//! (c) request-buffer occupancy — baseline vs DX100.
//! Paper: 3.9x BW, 2.7x RBH, 12.1x occupancy on average.
use dx100::config::SystemConfig;
use dx100::metrics::{bench_scale, geomean_of, run_suite};
use dx100::report;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let comps = run_suite(&SystemConfig::table3(), bench_scale(), false);
    println!("== Figure 10: bandwidth / RBH / occupancy ==");
    print!("{}", report::bandwidth_table(&comps));
    println!(
        "geomeans: BW {:.2}x (paper 3.9x) | RBH {:.2}x (paper 2.7x) | occupancy {:.2}x (paper 12.1x)",
        geomean_of(&comps, |c| c.bw_improvement()),
        geomean_of(&comps, |c| c.rbh_improvement()),
        geomean_of(&comps, |c| c.occupancy_improvement()),
    );
    println!("bench wall time {:.1}s", t0.elapsed().as_secs_f64());
}
