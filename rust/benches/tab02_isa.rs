//! Table 2: the DX100 ISA — encoding round-trip and per-pattern listings
//! for every Table 1 access shape, plus encode/decode throughput.
use dx100::dx100::isa::*;
use dx100::engine::harness::Harness;
use std::time::Instant;

fn main() {
    let mut h = Harness::new("tab02", "Table 2: DX100 instruction set");
    let patterns: Vec<(&str, Vec<Instruction>)> = vec![
        ("CG: LD A[B[j]], j=H[i]..H[i+1]", vec![
            Instruction::sld(DType::U32, 0x1000_0000, 0, 0, 1, 2, NO_TILE),
            Instruction::rng(2, 3, 0, 1, NO_TILE),
            Instruction::ild(DType::F32, 0x2000_0000, 4, 3, NO_TILE),
        ]),
        ("PRH: ST A[B[f(C[i])]]", vec![
            Instruction::sld(DType::U32, 0x3000_0000, 0, 0, 1, 2, NO_TILE),
            Instruction::alus(DType::U32, Op::And, 1, 0, 3, NO_TILE),
            Instruction::alus(DType::U32, Op::Shr, 2, 1, 4, NO_TILE),
            Instruction::ild(DType::U32, 0x4000_0000, 3, 2, NO_TILE),
            Instruction::ist(DType::U32, 0x5000_0000, 3, 4, NO_TILE),
        ]),
        ("PR: RMW A[B[j]] += C[i]", vec![
            Instruction::irmw(DType::F32, 0x6000_0000, Op::Add, 0, 1, NO_TILE),
        ]),
        ("BFS: cond ST A[B[j]] if D[E[j]] < F", vec![
            Instruction::ild(DType::U32, 0x7000_0000, 2, 0, NO_TILE),
            Instruction::alus(DType::U32, Op::Lt, 3, 2, 5, NO_TILE),
            Instruction::ist(DType::U32, 0x8000_0000, 0, 1, 3),
        ]),
    ];
    let mut listed = 0u64;
    for (name, insts) in &patterns {
        h.line(&format!("\n{name}"));
        for i in insts {
            let enc = i.encode();
            assert_eq!(Instruction::decode(enc).unwrap(), *i);
            h.line(&format!("  {i}"));
            listed += 1;
        }
    }
    h.metric("instructions_listed", listed as f64);
    // Encode/decode throughput (perf sanity of the 192b format).
    let inst = Instruction::irmw(DType::F64, 0xdead_0000, Op::Max, 7, 8, 9);
    let t0 = Instant::now();
    let mut acc = 0u64;
    const N: u64 = 5_000_000;
    for _ in 0..N {
        let e = inst.encode();
        acc = acc.wrapping_add(e[0] ^ e[2]);
        std::hint::black_box(Instruction::decode(std::hint::black_box(e)));
    }
    let dt = t0.elapsed().as_secs_f64();
    let mops = N as f64 / dt / 1e6;
    h.line(&format!("\nencode+decode: {mops:.1} M ops/s (acc {acc})"));
    h.metric("encode_decode_mops", mops);
    h.finish();
}
