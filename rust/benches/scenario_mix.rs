//! Multi-tenant mixes on one shared DX100: tenant pairs co-scheduled on
//! disjoint core groups, sharing the accelerator, LLC, and DRAM, under
//! every request-buffer arbitration policy.
//!
//! Where the figure benches evaluate workloads *solo*, this bench probes
//! what the paper's shared-resource design implies but never measures:
//! how much one tenant's indirection traffic costs another when both go
//! through the same DX100. Per (mix, policy) it reports each tenant's
//! slowdown vs its cached solo baseline, the row-hit-rate interference,
//! and Jain's fairness index across the tenants.
//!
//! Tenant workloads come from the registry (paper kernels + generated
//! scenarios), so solo baselines are served from the persisted result
//! cache when enabled, and the mixes themselves are bit-identical across
//! the `(DX100_THREADS, DX100_SHARDS)` matrix like every solo run.

use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::engine::mix::run_mix;
use dx100::engine::ExecOptions;
use dx100::workloads::mix::{ArbPolicy, MixSpec};
use dx100::workloads::Registry;

fn main() {
    let mut h = Harness::new("scenario_mix", "Multi-tenant mixes on one shared DX100");
    let reg = Registry::paper().with_synth();
    let cfg = SystemConfig::table3();
    let opts = ExecOptions::new();
    // Three contention archetypes: bandwidth vs locality-skewed traffic,
    // latency-bound chasing next to streaming gathers (phase-shifted so
    // the chaser starts into a warm accelerator), and a paper kernel
    // sharing with an atomic-RMW scenario.
    let mixes = [
        MixSpec::new()
            .tenant("uni-gather", 4)
            .tenant("zipf-gather", 4),
        MixSpec::new()
            .tenant("chase-gather", 4)
            .tenant_at("uni-gather", 4, 1000),
        MixSpec::new().tenant("CG", 4).tenant("hash-rmw", 4),
    ];
    h.line(&format!(
        "{} tenant pairs x {} arbitration policies",
        mixes.len(),
        ArbPolicy::ALL.len()
    ));
    let mut worst_fairness = f64::INFINITY;
    for (mi, mix) in mixes.iter().enumerate() {
        h.line(&format!("-- mix {}: {}", mi, mix.label()));
        for policy in ArbPolicy::ALL {
            let r = run_mix(mix, &reg, &cfg, h.scale(), policy, &opts)
                .expect("mix tenants come from the registry");
            let key = format!("m{mi}_{}", policy.label());
            h.line(&format!(
                "   {:<4} fairness {:.3}  (solo cache: {} hits / {} misses)",
                policy.label(),
                r.fairness,
                r.solo_cache_hits,
                r.solo_cache_misses,
            ));
            for t in &r.tenants {
                h.line(&format!(
                    "        {:<14} x{} slowdown {:5.2}x  rbh interference {:+.3}",
                    t.workload, t.cores, t.slowdown, t.row_hit_interference,
                ));
                h.metric(&format!("{key}_{}_slowdown", t.workload), t.slowdown);
                h.metric(
                    &format!("{key}_{}_rbh_interference", t.workload),
                    t.row_hit_interference,
                );
            }
            h.metric(&format!("{key}_fairness"), r.fairness);
            worst_fairness = worst_fairness.min(r.fairness);
            h.run(r.combined.workload, &r.combined);
        }
    }
    h.metric("worst_fairness", worst_fairness);
    h.paper("one DX100 serves multiple client cores' indirection streams (S4.1, S4.4)");
    h.finish();
}
