//! Figure 11: (a) core instruction reduction, (b) MPKI reduction.
//! Paper: 3.6x geomean instruction reduction; BFS slightly increases due
//! to synchronization spinning.
use dx100::config::SystemConfig;
use dx100::metrics::{bench_scale, geomean_of, run_suite};
use dx100::report;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let comps = run_suite(&SystemConfig::table3(), bench_scale(), false);
    println!("== Figure 11: instruction / MPKI reduction ==");
    print!("{}", report::instr_mpki_table(&comps));
    println!(
        "geomeans: instr {:.2}x (paper 3.6x) | MPKI {:.2}x",
        geomean_of(&comps, |c| c.instr_reduction()),
        geomean_of(&comps, |c| c.mpki_reduction()),
    );
    println!("bench wall time {:.1}s", t0.elapsed().as_secs_f64());
}
