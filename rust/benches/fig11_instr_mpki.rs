//! Figure 11: (a) core instruction reduction, (b) MPKI reduction.
//! Paper: 3.6x geomean instruction reduction; BFS slightly increases due
//! to synchronization spinning.
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::metrics::{comparisons_at, geomean_of, run_suite_sweep};
use dx100::report;

fn main() {
    let mut h = Harness::new("fig11", "Figure 11: instruction / MPKI reduction");
    let mut r = run_suite_sweep(&SystemConfig::table3(), h.scale(), false);
    h.sweep(&r);
    let comps = comparisons_at(r.points.remove(0));
    h.table(&report::instr_mpki_table(&comps));
    h.comparisons(&comps);
    let instr = geomean_of(&comps, |c| c.instr_reduction());
    let mpki = geomean_of(&comps, |c| c.mpki_reduction());
    h.metric("geomean_instr_reduction", instr);
    h.metric("geomean_mpki_reduction", mpki);
    h.paper(&format!(
        "instr 3.6x | measured: instr {instr:.2}x | MPKI {mpki:.2}x"
    ));
    h.finish();
}
