//! Figure 8a: All-Hits microbenchmarks.
//! Paper: Gather-SPD 1.2x, Gather-Full 3.2x, RMW-Atomic 17.8x,
//! RMW-NoAtom 3.7x, Scatter 6.6x.
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::metrics::compare_one;
use dx100::workloads::micro::{self, IndexPattern};

fn main() {
    let mut h = Harness::new("fig08_micro", "Figure 8a: All-Hits microbenchmarks");
    let cfg = SystemConfig::table3();
    let n = 1 << 16;
    let cases = [
        (micro::gather_spd(n, IndexPattern::Streaming, 1), 1.2),
        (micro::gather_full(n, IndexPattern::Streaming, 2), 3.2),
        (micro::rmw(n, true, IndexPattern::Streaming, 3), 17.8),
        (micro::rmw(n, false, IndexPattern::Streaming, 3), 3.7),
        (micro::scatter(n, IndexPattern::Streaming, 4), 6.6),
    ];
    h.line(&format!(
        "{:<12} {:>10} {:>10} {:>9} {:>9} {:>10}",
        "kernel", "base(cyc)", "dx(cyc)", "speedup", "paper", "instr red"
    ));
    for (w, paper) in &cases {
        let c = compare_one(w, &cfg, false);
        h.line(&format!(
            "{:<12} {:>10} {:>10} {:>8.2}x {:>8.1}x {:>9.1}x",
            c.workload,
            c.baseline.cycles,
            c.dx100.cycles,
            c.speedup(),
            paper,
            c.instr_reduction()
        ));
        h.comparisons(std::slice::from_ref(&c));
        h.metric(&format!("{}_speedup", c.workload), c.speedup());
    }
    h.paper("Gather-SPD 1.2x, Gather-Full 3.2x, RMW-Atomic 17.8x, RMW-NoAtom 3.7x, Scatter 6.6x");
    h.finish();
}
