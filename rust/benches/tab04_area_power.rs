//! Table 4: DX100 area and power breakdown (28 nm synthesis results
//! reproduced by the analytical model), plus the 14 nm projection and
//! processor overhead.
use dx100::config::SystemConfig;
use dx100::dx100::area::AreaReport;
use dx100::engine::harness::Harness;

fn main() {
    let mut h = Harness::new("tab04", "Table 4: DX100 area & power (28 nm)");
    let cfg = SystemConfig::table3();
    let r = AreaReport::for_config(&cfg.dx100);
    h.line(&format!(
        "{:<16} {:>10} {:>10}",
        "Module", "Area(mm2)", "Power(mW)"
    ));
    for (name, c) in r.components() {
        h.line(&format!(
            "{:<16} {:>10.3} {:>10.2}",
            name, c.area_mm2, c.power_mw
        ));
        h.metric(&format!("{name}_area_mm2"), c.area_mm2);
        h.metric(&format!("{name}_power_mw"), c.power_mw);
    }
    let t = r.total();
    h.line(&format!(
        "{:<16} {:>10.3} {:>10.2}   (paper: 4.061 / 777.17)",
        "Total", t.area_mm2, t.power_mw
    ));
    h.metric("total_area_mm2", t.area_mm2);
    h.metric("total_power_mw", t.power_mw);
    h.metric("total_area_14nm_mm2", r.total_area_14nm());
    h.metric("processor_overhead_4core", r.processor_overhead(4));
    h.line(&format!(
        "14nm: {:.2} mm2 (paper ~1.5); overhead vs 4-core CPU: {:.1}% (paper 3.7%)",
        r.total_area_14nm(),
        r.processor_overhead(4) * 100.0
    ));
    // Sensitivity: scratchpad dominates; smaller tiles shrink it.
    for tile in [1024usize, 4096, 16384] {
        let mut d = cfg.dx100.clone();
        d.tile_elems = tile;
        let rr = AreaReport::for_config(&d);
        h.line(&format!(
            "  tile={tile:>6}: total {:.3} mm2",
            rr.total().area_mm2
        ));
        h.metric(&format!("tile{tile}_total_area_mm2"), rr.total().area_mm2);
    }
    h.paper("total 4.061 mm2 / 777.17 mW at 28 nm; ~1.5 mm2 at 14 nm; 3.7% of 4 cores");
    h.finish();
}
