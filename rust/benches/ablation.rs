//! Ablation study over DX100's three mechanisms (DESIGN.md §4 design
//! choices): the *reordering window* (Row-Table BCAM rows), the
//! *coalescing* capacity (SRAM columns per row), the *fill rate* (address
//! translation/insert throughput), and the controller's FR-FCFS visibility
//! (request-buffer depth) for the baseline.
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::metrics::compare_one;
use dx100::workloads::micro::{self, AllMissOrder};

fn main() {
    let mut h = Harness::new(
        "ablation",
        "Ablation: which mechanism buys what (worst-order all-miss gather)",
    );
    // Miss-dominated gather over 16 rows x all banks (the §6.1 All-Misses
    // set in its worst ordering) — large enough that the reordering window
    // actually binds.
    let dram = SystemConfig::table3().dram;
    let w = micro::gather_allmiss(
        &dram,
        16,
        AllMissOrder {
            rbh: 0.0,
            chi: false,
            bgi: false,
        },
    );

    h.line("\nRow-Table rows per slice (reordering window):");
    for rows in [4usize, 16, 64, 256] {
        let mut cfg = SystemConfig::table3();
        cfg.dx100.rowtab_rows = rows;
        let c = compare_one(&w, &cfg, false);
        h.line(&format!(
            "  rows={rows:>4}: speedup {:.2}x, dx RBH {:.1}%, dx BW {:.1}%",
            c.speedup(),
            c.dx100.row_hit_rate * 100.0,
            c.dx100.bw_util * 100.0
        ));
        h.comparisons_tagged(std::slice::from_ref(&c), &format!("@rows{rows}"));
        h.metric(&format!("rows{rows}_speedup"), c.speedup());
    }

    h.line("\nRow-Table columns per row (coalescing capacity):");
    for cols in [1usize, 2, 8, 16] {
        let mut cfg = SystemConfig::table3();
        cfg.dx100.rowtab_cols = cols;
        let c = compare_one(&w, &cfg, false);
        let coalesce = c
            .dx100
            .dx
            .first()
            .map(|d| d.coalesce_factor())
            .unwrap_or(0.0);
        h.line(&format!(
            "  cols={cols:>3}: speedup {:.2}x, coalesce {coalesce:.2} words/access",
            c.speedup()
        ));
        h.comparisons_tagged(std::slice::from_ref(&c), &format!("@cols{cols}"));
        h.metric(&format!("cols{cols}_speedup"), c.speedup());
        h.metric(&format!("cols{cols}_coalesce"), coalesce);
    }

    h.line("\nIndirect-unit fill rate (indices/cycle):");
    for rate in [1usize, 2, 4, 16] {
        let mut cfg = SystemConfig::table3();
        cfg.dx100.fill_rate = rate;
        let c = compare_one(&w, &cfg, false);
        h.line(&format!("  fill={rate:>3}: speedup {:.2}x", c.speedup()));
        h.comparisons_tagged(std::slice::from_ref(&c), &format!("@fill{rate}"));
        h.metric(&format!("fill{rate}_speedup"), c.speedup());
    }

    h.line("\nBaseline FR-FCFS request buffer (controller visibility):");
    for buf in [8usize, 32, 128] {
        let mut cfg = SystemConfig::table3();
        cfg.dram.request_buffer = buf;
        let c = compare_one(&w, &cfg, false);
        h.line(&format!(
            "  buffer={buf:>4}: baseline RBH {:.1}%, BW {:.1}% (DX100 speedup {:.2}x)",
            c.baseline.row_hit_rate * 100.0,
            c.baseline.bw_util * 100.0,
            c.speedup()
        ));
        h.comparisons_tagged(std::slice::from_ref(&c), &format!("@buf{buf}"));
        h.metric(&format!("buf{buf}_speedup"), c.speedup());
    }
    h.finish();
}
