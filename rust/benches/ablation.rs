//! Ablation study over DX100's three mechanisms (DESIGN.md §4 design
//! choices): the *reordering window* (Row-Table BCAM rows), the
//! *coalescing* capacity (SRAM columns per row), the *fill rate* (address
//! translation/insert throughput), and the controller's FR-FCFS visibility
//! (request-buffer depth) for the baseline.
//!
//! Runs as one 15-point SweepPlan over a single workload: the front end
//! compiles once for the whole study, config points that agree on the
//! compiler-relevant knobs share one DX100 specialization, points whose
//! *full* config matches the Table-3 default (rows=64, cols=8, fill=4,
//! buf=32 are all the same machine) share one simulation, and unchanged
//! cells replay from the persisted result cache.
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::engine::{ExecOptions, PointResult, Sweep};
use dx100::metrics::{comparisons_at, Comparison};
use dx100::workloads::micro::{self, AllMissOrder};

const ROWS: [usize; 4] = [4, 16, 64, 256];
const COLS: [usize; 4] = [1, 2, 8, 16];
const FILLS: [usize; 4] = [1, 2, 4, 16];
const BUFS: [usize; 3] = [8, 32, 128];

fn one(point: PointResult) -> Comparison {
    comparisons_at(point)
        .into_iter()
        .next()
        .expect("one workload per point")
}

fn main() {
    let mut h = Harness::new(
        "ablation",
        "Ablation: which mechanism buys what (worst-order all-miss gather)",
    );
    // Miss-dominated gather over 16 rows x all banks (the §6.1 All-Misses
    // set in its worst ordering) — large enough that the reordering window
    // actually binds.
    let dram = SystemConfig::table3().dram;
    let w = micro::gather_allmiss(
        &dram,
        16,
        AllMissOrder {
            rbh: 0.0,
            chi: false,
            bgi: false,
        },
    );

    let mut sweep = Sweep::new().workload(w);
    for rows in ROWS {
        let mut cfg = SystemConfig::table3();
        cfg.dx100.rowtab_rows = rows;
        sweep = sweep.point(format!("rows{rows}"), cfg);
    }
    for cols in COLS {
        let mut cfg = SystemConfig::table3();
        cfg.dx100.rowtab_cols = cols;
        sweep = sweep.point(format!("cols{cols}"), cfg);
    }
    for rate in FILLS {
        let mut cfg = SystemConfig::table3();
        cfg.dx100.fill_rate = rate;
        sweep = sweep.point(format!("fill{rate}"), cfg);
    }
    for buf in BUFS {
        let mut cfg = SystemConfig::table3();
        cfg.dram.request_buffer = buf;
        sweep = sweep.point(format!("buf{buf}"), cfg);
    }
    let r = sweep.execute(&ExecOptions::new());
    h.sweep(&r);
    let mut points = r.points.into_iter();

    h.line("\nRow-Table rows per slice (reordering window):");
    for rows in ROWS {
        let c = one(points.next().expect("rows point"));
        h.line(&format!(
            "  rows={rows:>4}: speedup {:.2}x, dx RBH {:.1}%, dx BW {:.1}%",
            c.speedup(),
            c.dx100.row_hit_rate * 100.0,
            c.dx100.bw_util * 100.0
        ));
        h.comparisons_tagged(std::slice::from_ref(&c), &format!("@rows{rows}"));
        h.metric(&format!("rows{rows}_speedup"), c.speedup());
    }

    h.line("\nRow-Table columns per row (coalescing capacity):");
    for cols in COLS {
        let c = one(points.next().expect("cols point"));
        let coalesce = c
            .dx100
            .dx
            .first()
            .map(|d| d.coalesce_factor())
            .unwrap_or(0.0);
        h.line(&format!(
            "  cols={cols:>3}: speedup {:.2}x, coalesce {coalesce:.2} words/access",
            c.speedup()
        ));
        h.comparisons_tagged(std::slice::from_ref(&c), &format!("@cols{cols}"));
        h.metric(&format!("cols{cols}_speedup"), c.speedup());
        h.metric(&format!("cols{cols}_coalesce"), coalesce);
    }

    h.line("\nIndirect-unit fill rate (indices/cycle):");
    for rate in FILLS {
        let c = one(points.next().expect("fill point"));
        h.line(&format!("  fill={rate:>3}: speedup {:.2}x", c.speedup()));
        h.comparisons_tagged(std::slice::from_ref(&c), &format!("@fill{rate}"));
        h.metric(&format!("fill{rate}_speedup"), c.speedup());
    }

    h.line("\nBaseline FR-FCFS request buffer (controller visibility):");
    for buf in BUFS {
        let c = one(points.next().expect("buf point"));
        h.line(&format!(
            "  buffer={buf:>4}: baseline RBH {:.1}%, BW {:.1}% (DX100 speedup {:.2}x)",
            c.baseline.row_hit_rate * 100.0,
            c.baseline.bw_util * 100.0,
            c.speedup()
        ));
        h.comparisons_tagged(std::slice::from_ref(&c), &format!("@buf{buf}"));
        h.metric(&format!("buf{buf}_speedup"), c.speedup());
    }
    h.finish();
}
