//! Figure 12: DX100 vs the DMP indirect prefetcher.
//! Paper: 2.0x speedup, 3.3x bandwidth utilization over DMP.
//!
//! Runs as a single-point SweepPlan over all three systems, so unchanged
//! reruns replay from the persisted result cache.
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::engine::{ExecOptions, Sweep};
use dx100::metrics::comparisons_at;
use dx100::util::geomean;
use dx100::workloads;

fn main() {
    let mut h = Harness::new("fig12", "Figure 12: DX100 vs DMP");
    let r = Sweep::new()
        .with_dmp()
        .point("", SystemConfig::table3())
        .workloads(workloads::all(h.scale()))
        .execute(&ExecOptions::new());
    h.sweep(&r);
    let comps = comparisons_at(r.points.into_iter().next().expect("one point"));
    h.line(&format!(
        "{:<8} {:>9} {:>9} {:>9} {:>8} | {:>7} {:>7}",
        "workload", "base", "dmp", "dx", "vs dmp", "dmpBW%", "dxBW%"
    ));
    let mut sp = Vec::new();
    let mut bw = Vec::new();
    for c in &comps {
        let d = c.dmp.as_ref().unwrap();
        let s = d.cycles as f64 / c.dx100.cycles as f64;
        sp.push(s);
        bw.push(c.dx100.bw_util / d.bw_util.max(1e-9));
        h.line(&format!(
            "{:<8} {:>9} {:>9} {:>9} {:>7.2}x | {:>6.1}% {:>6.1}%",
            c.workload,
            c.baseline.cycles,
            d.cycles,
            c.dx100.cycles,
            s,
            d.bw_util * 100.0,
            c.dx100.bw_util * 100.0
        ));
    }
    h.comparisons(&comps);
    let (gs, gb) = (geomean(&sp), geomean(&bw));
    h.metric("geomean_speedup_vs_dmp", gs);
    h.metric("geomean_bw_vs_dmp", gb);
    h.paper(&format!(
        "2.0x speedup, 3.3x BW vs DMP | measured: {gs:.2}x speedup | {gb:.2}x BW"
    ));
    h.finish();
}
