//! Figure 12: DX100 vs the DMP indirect prefetcher.
//! Paper: 2.0x speedup, 3.3x bandwidth utilization over DMP.
use dx100::config::SystemConfig;
use dx100::metrics::{bench_scale, run_suite};
use dx100::util::geomean;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let comps = run_suite(&SystemConfig::table3(), bench_scale(), true);
    println!("== Figure 12: DX100 vs DMP ==");
    println!(
        "{:<8} {:>9} {:>9} {:>9} {:>8} | {:>7} {:>7}",
        "workload", "base", "dmp", "dx", "vs dmp", "dmpBW%", "dxBW%"
    );
    let mut sp = Vec::new();
    let mut bw = Vec::new();
    for c in &comps {
        let d = c.dmp.as_ref().unwrap();
        let s = d.cycles as f64 / c.dx100.cycles as f64;
        sp.push(s);
        bw.push(c.dx100.bw_util / d.bw_util.max(1e-9));
        println!(
            "{:<8} {:>9} {:>9} {:>9} {:>7.2}x | {:>6.1}% {:>6.1}%",
            c.workload,
            c.baseline.cycles,
            d.cycles,
            c.dx100.cycles,
            s,
            d.bw_util * 100.0,
            c.dx100.bw_util * 100.0
        );
    }
    println!(
        "geomean speedup vs DMP: {:.2}x (paper 2.0x) | BW vs DMP: {:.2}x (paper 3.3x)",
        geomean(&sp),
        geomean(&bw)
    );
    println!("bench wall time {:.1}s", t0.elapsed().as_secs_f64());
}
