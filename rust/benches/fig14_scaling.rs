//! Figure 14: scalability — 4 cores/2ch vs 8 cores/4ch with one or two
//! DX100 instances. Paper: 2.6x (4c), 2.5x (8c, 1x), 2.7x (8c, 2x).
use dx100::config::SystemConfig;
use dx100::metrics::{bench_scale, geomean_of, run_suite};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    println!("== Figure 14: core / DX100-instance scaling ==");
    let configs = [
        ("4 cores, 2ch, 1x DX100", SystemConfig::table3(), 1, 2.6),
        ("8 cores, 4ch, 1x DX100", SystemConfig::table3_8core(), 1, 2.5),
        ("8 cores, 4ch, 2x DX100", SystemConfig::table3_8core(), 2, 2.7),
    ];
    for (name, mut cfg, instances, paper) in configs {
        cfg.dx100.instances = instances;
        let comps = run_suite(&cfg, bench_scale(), false);
        println!(
            "{name}: geomean speedup {:.2}x (paper {paper}x)",
            geomean_of(&comps, |c| c.speedup())
        );
    }
    println!("bench wall time {:.1}s", t0.elapsed().as_secs_f64());
}
