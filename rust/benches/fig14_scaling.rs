//! Figure 14: scalability — 4 cores/2ch vs 8 cores/4ch with one or two
//! DX100 instances. Paper: 2.6x (4c), 2.5x (8c, 1x), 2.7x (8c, 2x).
//!
//! Runs as one SweepPlan: the three system points share a single worker
//! pool and one front-end compilation per workload; results replay from
//! the persisted cache on unchanged reruns.
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::engine::{ExecOptions, Sweep};
use dx100::metrics::{comparisons_at, geomean_of};
use dx100::workloads;

fn main() {
    let mut h = Harness::new("fig14", "Figure 14: core / DX100-instance scaling");
    let configs = [
        ("4c2ch1x", "4 cores, 2ch, 1x DX100", SystemConfig::table3(), 1, 2.6),
        ("8c4ch1x", "8 cores, 4ch, 1x DX100", SystemConfig::table3_8core(), 1, 2.5),
        ("8c4ch2x", "8 cores, 4ch, 2x DX100", SystemConfig::table3_8core(), 2, 2.7),
    ];
    let mut sweep = Sweep::new().workloads(workloads::all(h.scale()));
    for (tag, _, cfg, instances, _) in &configs {
        let mut cfg = cfg.clone();
        cfg.dx100.instances = *instances;
        sweep = sweep.point(*tag, cfg);
    }
    let r = sweep.execute(&ExecOptions::new());
    h.sweep(&r);
    for (point, (tag, name, _, _, paper)) in r.points.into_iter().zip(configs) {
        let comps = comparisons_at(point);
        let g = geomean_of(&comps, |c| c.speedup());
        h.line(&format!("{name}: geomean speedup {g:.2}x (paper {paper}x)"));
        h.comparisons_tagged(&comps, &format!("@{tag}"));
        h.metric(&format!("{tag}_geomean_speedup"), g);
    }
    h.paper("2.6x (4c), 2.5x (8c, 1x DX100), 2.7x (8c, 2x DX100)");
    h.finish();
}
