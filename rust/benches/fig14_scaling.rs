//! Figure 14: scalability — 4 cores/2ch vs 8 cores/4ch with one or two
//! DX100 instances. Paper: 2.6x (4c), 2.5x (8c, 1x), 2.7x (8c, 2x).
use dx100::config::SystemConfig;
use dx100::engine::harness::Harness;
use dx100::metrics::{geomean_of, run_suite};

fn main() {
    let mut h = Harness::new("fig14", "Figure 14: core / DX100-instance scaling");
    let configs = [
        ("4c2ch1x", "4 cores, 2ch, 1x DX100", SystemConfig::table3(), 1, 2.6),
        ("8c4ch1x", "8 cores, 4ch, 1x DX100", SystemConfig::table3_8core(), 1, 2.5),
        ("8c4ch2x", "8 cores, 4ch, 2x DX100", SystemConfig::table3_8core(), 2, 2.7),
    ];
    for (tag, name, mut cfg, instances, paper) in configs {
        cfg.dx100.instances = instances;
        let comps = run_suite(&cfg, h.scale(), false);
        let g = geomean_of(&comps, |c| c.speedup());
        h.line(&format!("{name}: geomean speedup {g:.2}x (paper {paper}x)"));
        h.comparisons_tagged(&comps, &format!("@{tag}"));
        h.metric(&format!("{tag}_geomean_speedup"), g);
    }
    h.paper("2.6x (4c), 2.5x (8c, 1x DX100), 2.7x (8c, 2x DX100)");
    h.finish();
}
