"""AOT compilation: lower every Layer-2 model function to HLO **text** in
``artifacts/``.

HLO text (not a serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the Rust `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _tuplify(fn):
    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def build_all(out_dir: str) -> list:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, (fn, specs) in sorted(model.export_table().items()):
        lowered = jax.jit(_tuplify(fn)).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        arg_desc = ";".join(
            f"{'x'.join(str(d) for d in s.shape) or 'scalar'}:{s.dtype}" for s in specs
        )
        manifest.append(f"{name} {arg_desc}")
        print(f"  wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write(f"tile={model.TILE} data_n={model.DATA_N} range_cap={model.RANGE_CAP}\n")
        f.write("\n".join(manifest) + "\n")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    built = build_all(args.out_dir)
    print(f"built {len(built)} artifacts in {args.out_dir}")


if __name__ == "__main__":
    main()
