"""Pallas vector-ALU kernels (DX100 ALUV/ALUS, 16 lanes in hardware).

One kernel per operation — DX100's OP field is an immediate, so each (op,
dtype) pair lowers to its own executable, exactly like the AOT artifacts the
Rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512

_OPS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "min": jnp.minimum,
    "max": jnp.maximum,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
    "shr": lambda a, b: a >> b,
    "shl": lambda a, b: a << b,
    "lt": lambda a, b: (a < b).astype(a.dtype),
    "le": lambda a, b: (a <= b).astype(a.dtype),
    "gt": lambda a, b: (a > b).astype(a.dtype),
    "ge": lambda a, b: (a >= b).astype(a.dtype),
    "eq": lambda a, b: (a == b).astype(a.dtype),
}


def _blocking(n):
    if n % BLOCK == 0 and n >= BLOCK:
        return (n // BLOCK,), BLOCK
    return (1,), n


def _aluv_call(op, a, b):
    fn = _OPS[op]

    def kernel(a_ref, b_ref, o_ref):
        o_ref[...] = fn(a_ref[...], b_ref[...])

    n = a.shape[0]
    grid, block = _blocking(n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("op",))
def aluv(a, b, op: str):
    """Tile-wise `a OP b` (DX100 ALUV)."""
    return _aluv_call(op, a, b)


@functools.partial(jax.jit, static_argnames=("op",))
def alus(a, scalar, op: str):
    """Tile-vs-scalar `a OP s` (DX100 ALUS); scalar is a 0-d array."""
    b = jnp.broadcast_to(scalar.astype(a.dtype), a.shape)
    return _aluv_call(op, a, b)


@jax.jit
def hash_index(keys, mask, shift):
    """Fused Hash-Join address calc (C & mask) >> shift as two ALUS steps."""
    masked = alus(keys, mask, op="and")
    return alus(masked, shift, op="shr")
