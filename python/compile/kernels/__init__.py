"""Layer-1 Pallas kernels: the tile-granularity data path of DX100's
functional units (gather, vector ALU, RMW-combine), plus the pure-jnp
reference oracles in `ref`.

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime loads. See DESIGN.md §Hardware-Adaptation for the TPU mapping.
"""

from . import alu, gather, ref, rmw  # noqa: F401
