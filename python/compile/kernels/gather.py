"""Pallas tile-gather kernel: out[i] = data[idx[i]].

The index tile is BlockSpec-tiled over the grid (the HBM->VMEM schedule);
the data array is presented whole to each block — on a real TPU it would be
resident in VMEM for the working sets DX100 targets (a 64 KB tile and the
hot region of the indirect array), with the Row-Table analog being the block
schedule itself. ``interpret=True`` everywhere: CPU PJRT cannot run Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elements processed per grid step.
BLOCK = 512


def _gather_block(idx_ref, data_ref, o_ref):
    """One block: vector gather from the (whole) data ref."""
    idx = idx_ref[...]
    o_ref[...] = data_ref[idx]


@functools.partial(jax.jit, static_argnames=())
def gather(data, idx):
    """out[i] = data[idx[i]] as a Pallas kernel over BLOCK-element tiles."""
    n = idx.shape[0]
    if n % BLOCK == 0 and n >= BLOCK:
        grid = (n // BLOCK,)
        block = BLOCK
    else:
        grid = (1,)
        block = n
    return pl.pallas_call(
        _gather_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(data.shape, lambda i: tuple(0 for _ in data.shape)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), data.dtype),
        interpret=True,
    )(idx, data)


def _gather_cond_block(idx_ref, cond_ref, data_ref, o_ref):
    idx = idx_ref[...]
    cond = cond_ref[...]
    g = data_ref[idx]
    o_ref[...] = jnp.where(cond != 0, g, jnp.zeros((), g.dtype))


@jax.jit
def gather_cond(data, idx, cond):
    """Conditioned gather (ILD with a TC tile): untaken lanes produce 0."""
    n = idx.shape[0]
    if n % BLOCK == 0 and n >= BLOCK:
        grid = (n // BLOCK,)
        block = BLOCK
    else:
        grid = (1,)
        block = n
    return pl.pallas_call(
        _gather_cond_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(data.shape, lambda i: tuple(0 for _ in data.shape)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), data.dtype),
        interpret=True,
    )(idx, cond, data)
