"""Pallas RMW-combine kernel: the Word Modifier's arithmetic step
(DX100 IRMW). Only associative + commutative ops are legal because the
Indirect unit reorders operations (paper §3.1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512

_RMW_OPS = {
    "add": lambda a, b: a + b,
    "min": jnp.minimum,
    "max": jnp.maximum,
}

ILLEGAL = ("sub", "shl", "shr", "lt", "gt")


@functools.partial(jax.jit, static_argnames=("op",))
def rmw_combine(old, val, op: str):
    """new[i] = old[i] OP val[i] for an associative+commutative OP."""
    if op not in _RMW_OPS:
        raise ValueError(f"IRMW op must be associative+commutative, got {op}")
    fn = _RMW_OPS[op]

    def kernel(old_ref, val_ref, o_ref):
        o_ref[...] = fn(old_ref[...], val_ref[...])

    n = old.shape[0]
    if n % BLOCK == 0 and n >= BLOCK:
        grid, block = (n // BLOCK,), BLOCK
    else:
        grid, block = (1,), n
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), old.dtype),
        interpret=True,
    )(old, val)
