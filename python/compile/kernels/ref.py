"""Pure-jnp reference oracles for every Pallas kernel and L2 model op.

These are the correctness anchors: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas kernels (and the Rust functional
simulator, transitively through the e2e example) match these functions.
"""

import jax.numpy as jnp


def gather(data, idx):
    """out[i] = data[idx[i]]."""
    return data[idx]


def gather_cond(data, idx, cond):
    """Conditioned gather: untaken lanes produce 0 (DX100 ILD semantics)."""
    return jnp.where(cond != 0, data[idx], jnp.zeros((), data.dtype))


def alu(a, b, op: str):
    """Vector ALU reference. Comparison ops return 0/1 in a's dtype."""
    if op == "add":
        return a + b
    if op == "sub":
        return a - b
    if op == "mul":
        return a * b
    if op == "min":
        return jnp.minimum(a, b)
    if op == "max":
        return jnp.maximum(a, b)
    if op == "and":
        return a & b
    if op == "or":
        return a | b
    if op == "xor":
        return a ^ b
    if op == "shr":
        return a >> b
    if op == "shl":
        return a << b
    if op == "lt":
        return (a < b).astype(a.dtype)
    if op == "le":
        return (a <= b).astype(a.dtype)
    if op == "gt":
        return (a > b).astype(a.dtype)
    if op == "ge":
        return (a >= b).astype(a.dtype)
    if op == "eq":
        return (a == b).astype(a.dtype)
    raise ValueError(f"unknown op {op}")


def rmw_combine(old, val, op: str):
    """RMW combine step (the Word Modifier's arithmetic)."""
    if op == "add":
        return old + val
    if op == "min":
        return jnp.minimum(old, val)
    if op == "max":
        return jnp.maximum(old, val)
    raise ValueError(f"IRMW op must be associative+commutative, got {op}")


def scatter_add(data, idx, vals):
    """data[idx[i]] += vals[i] with duplicate-index accumulation."""
    return data.at[idx].add(vals)


def scatter_set(data, idx, vals):
    """data[idx[i]] = vals[i]; for duplicates the last write wins."""
    return data.at[idx].set(vals)


def range_fuse(lo, hi, cap):
    """Flatten `for i: for j in lo[i]..hi[i]` into (outer, inner, count),
    padded to `cap` (DX100 Range Fuser, Figure 5).

    Vectorized: position k of the output belongs to outer iteration
    `searchsorted(ends, k, 'right')`, with inner offset k - starts[i].
    """
    lens = jnp.maximum(hi - lo, 0)
    ends = jnp.cumsum(lens)
    total = ends[-1] if lens.size else jnp.uint32(0)
    k = jnp.arange(cap, dtype=lens.dtype)
    outer = jnp.searchsorted(ends, k, side="right").astype(lens.dtype)
    outer_c = jnp.minimum(outer, lens.size - 1)
    starts = ends - lens
    inner = lo[outer_c] + (k - starts[outer_c])
    valid = k < total
    outer = jnp.where(valid, outer_c, 0)
    inner = jnp.where(valid, inner, 0)
    return outer, inner, total


def spmv_tile(vals, col, row, x, y):
    """One SpMV tile: y[row[k]] += vals[k] * x[col[k]] (CG inner loop)."""
    return y.at[row].add(vals * x[col])


def gather_axpy(data, idx, c, alpha):
    """out[i] = alpha * data[idx[i]] + c[i] (fused gather + ALU)."""
    return alpha * data[idx] + c


def hash_index(keys, mask, shift):
    """Hash-Join address calculation f(C[i]) = (C[i] & mask) >> shift."""
    return (keys & mask) >> shift
