"""Layer-2 JAX model: the tile-granularity dataflow of DX100's functional
units, composed from the Layer-1 Pallas kernels.

Everything here is build-time only: `aot.py` lowers these functions once to
HLO text in `artifacts/`, and the Rust runtime executes them via PJRT. The
shapes exported are fixed (AOT), matching the constants below.
"""

import jax
import jax.numpy as jnp

from .kernels import alu as k_alu
from .kernels import gather as k_gather
from .kernels import rmw as k_rmw

# AOT export shapes (the Rust runtime mirrors these; see aot.py manifest).
TILE = 4096
DATA_N = 1 << 18  # 262,144 elements (1 MiB of f32)
RANGE_CAP = 4 * TILE


def gather_f32(data, idx):
    """ILD: out[i] = data[idx[i]] (Pallas gather kernel)."""
    return k_gather.gather(data, idx)


def gather_cond_f32(data, idx, cond):
    """Conditioned ILD."""
    return k_gather.gather_cond(data, idx, cond)


def scatter_add_f32(data, idx, vals):
    """IRMW(add): data[idx[i]] += vals[i]; duplicate indices accumulate.

    The scatter itself is an L2 XLA scatter (the reorder/coalesce step is
    DX100 hardware, not data math); the combine arithmetic is the L1
    rmw_combine kernel applied to the gathered old values — exercised here
    so the kernel sits on the artifact's compute path.
    """
    old = k_gather.gather(data, idx)
    new = k_rmw.rmw_combine(old, vals, op="add")
    delta = new - old  # == vals, but keeps the kernel in the graph
    return data.at[idx].add(delta)


def scatter_set_f32(data, idx, vals):
    """IST: data[idx[i]] = vals[i] (last write wins on duplicates)."""
    return data.at[idx].set(vals)


def range_fuse_u32(lo, hi):
    """RNG: flatten ranges into (outer, inner, count) padded to RANGE_CAP."""
    from .kernels import ref

    return ref.range_fuse(lo, hi, RANGE_CAP)


def alu_f32(a, b, op="add"):
    """ALUV over f32 tiles."""
    return k_alu.aluv(a, b, op=op)


def hash_index_u32(keys, mask, shift):
    """Hash-Join address calc as two chained ALUS kernels."""
    return k_alu.hash_index(keys, mask, shift)


def gather_axpy_f32(data, idx, c, alpha):
    """Fused ILD + ALU: out = alpha * data[idx] + c."""
    g = k_gather.gather(data, idx)
    scaled = k_alu.alus(g, alpha, op="mul")
    return k_alu.aluv(scaled, c, op="add")


def spmv_tile_f32(vals, col, row, x, y):
    """One CG/SpMV tile: y[row[k]] += vals[k] * x[col[k]].

    The gather of x is the L1 Pallas kernel; the row accumulation is an XLA
    scatter-add (DX100's IRMW path).
    """
    xg = k_gather.gather(x, col)
    prod = k_alu.aluv(vals, xg, op="mul")
    return y.at[row].add(prod)


# ---------------------------------------------------------------------------
# AOT export table: name -> (function, example argument shapes/dtypes).
# ---------------------------------------------------------------------------


def _s(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_table():
    """Every artifact the Rust runtime can load."""
    f32 = jnp.float32
    i32 = jnp.int32
    u32 = jnp.uint32
    return {
        "gather_f32": (
            gather_f32,
            (_s((DATA_N,), f32), _s((TILE,), i32)),
        ),
        "gather_cond_f32": (
            gather_cond_f32,
            (_s((DATA_N,), f32), _s((TILE,), i32), _s((TILE,), i32)),
        ),
        "scatter_add_f32": (
            scatter_add_f32,
            (_s((DATA_N,), f32), _s((TILE,), i32), _s((TILE,), f32)),
        ),
        "scatter_set_f32": (
            scatter_set_f32,
            (_s((DATA_N,), f32), _s((TILE,), i32), _s((TILE,), f32)),
        ),
        "range_fuse_u32": (
            range_fuse_u32,
            (_s((TILE,), u32), _s((TILE,), u32)),
        ),
        "alu_add_f32": (
            lambda a, b: alu_f32(a, b, op="add"),
            (_s((TILE,), f32), _s((TILE,), f32)),
        ),
        "alu_mul_f32": (
            lambda a, b: alu_f32(a, b, op="mul"),
            (_s((TILE,), f32), _s((TILE,), f32)),
        ),
        "alu_ge_f32": (
            lambda a, b: alu_f32(a, b, op="ge"),
            (_s((TILE,), f32), _s((TILE,), f32)),
        ),
        "hash_index_u32": (
            hash_index_u32,
            (_s((TILE,), u32), _s((), u32), _s((), u32)),
        ),
        "gather_axpy_f32": (
            gather_axpy_f32,
            (_s((DATA_N,), f32), _s((TILE,), i32), _s((TILE,), f32), _s((), f32)),
        ),
        "spmv_tile_f32": (
            spmv_tile_f32,
            (
                _s((TILE,), f32),
                _s((TILE,), i32),
                _s((TILE,), i32),
                _s((DATA_N,), f32),
                _s((DATA_N,), f32),
            ),
        ),
    }
