"""Layer-2 correctness: model-level tile ops vs scalar references, plus AOT
export sanity (every artifact lowers to HLO text containing an entry
computation).
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


@given(data=st.data())
@settings(**SETTINGS)
def test_scatter_add_accumulates_duplicates(data):
    n, t = 64, 128
    d = np.zeros(n, dtype=np.float32)
    idx = np.array(
        data.draw(st.lists(st.integers(0, n - 1), min_size=t, max_size=t)),
        dtype=np.int32,
    )
    vals = np.array(
        data.draw(st.lists(st.floats(-10, 10, width=32), min_size=t, max_size=t)),
        dtype=np.float32,
    )
    got = model.scatter_add_f32(jnp.asarray(d), jnp.asarray(idx), jnp.asarray(vals))
    want = d.copy()
    for i, v in zip(idx, vals):
        want[i] += v
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_scatter_set_last_write_wins():
    d = jnp.zeros(8, jnp.float32)
    idx = jnp.asarray([1, 1, 2], dtype=jnp.int32)
    vals = jnp.asarray([5.0, 7.0, 9.0], dtype=jnp.float32)
    got = np.asarray(model.scatter_set_f32(d, idx, vals))
    assert got[1] == 7.0
    assert got[2] == 9.0


@given(data=st.data())
@settings(**SETTINGS)
def test_range_fuse_matches_python_loop(data):
    n = data.draw(st.integers(1, 32))
    lo = np.array(
        data.draw(st.lists(st.integers(0, 20), min_size=n, max_size=n)),
        dtype=np.uint32,
    )
    spans = np.array(
        data.draw(st.lists(st.integers(0, 6), min_size=n, max_size=n)),
        dtype=np.uint32,
    )
    hi = lo + spans
    cap = int(spans.sum()) + 8
    outer, inner, total = ref.range_fuse(jnp.asarray(lo), jnp.asarray(hi), cap)
    # Scalar reference.
    exp_outer, exp_inner = [], []
    for i in range(n):
        for j in range(int(lo[i]), int(hi[i])):
            exp_outer.append(i)
            exp_inner.append(j)
    assert int(total) == len(exp_outer)
    np.testing.assert_array_equal(np.asarray(outer)[: len(exp_outer)], exp_outer)
    np.testing.assert_array_equal(np.asarray(inner)[: len(exp_inner)], exp_inner)
    # Padding is zeroed.
    assert np.all(np.asarray(outer)[len(exp_outer):] == 0)


@given(data=st.data())
@settings(**SETTINGS)
def test_spmv_tile_matches_dense(data):
    n, nnz = 32, 96
    rng_seed = data.draw(st.integers(0, 2**31))
    rng = np.random.default_rng(rng_seed)
    vals = rng.standard_normal(nnz).astype(np.float32)
    col = rng.integers(0, n, nnz).astype(np.int32)
    row = rng.integers(0, n, nnz).astype(np.int32)
    x = rng.standard_normal(n).astype(np.float32)
    y = np.zeros(n, dtype=np.float32)
    got = model.spmv_tile_f32(
        jnp.asarray(vals), jnp.asarray(col), jnp.asarray(row), jnp.asarray(x), jnp.asarray(y)
    )
    dense = np.zeros((n, n), dtype=np.float32)
    for v, c, r in zip(vals, col, row):
        dense[r, c] += v
    np.testing.assert_allclose(got, dense @ x, rtol=1e-3, atol=1e-3)


def test_gather_axpy_fused():
    d = jnp.arange(64, dtype=jnp.float32)
    idx = jnp.asarray([3, 1, 4, 1, 5], dtype=jnp.int32)
    c = jnp.ones(5, jnp.float32)
    got = model.gather_axpy_f32(d, idx, c, jnp.float32(2.0))
    np.testing.assert_allclose(got, 2.0 * np.asarray(d)[np.asarray(idx)] + 1.0)


def test_export_table_lowers_to_hlo():
    import jax
    from compile.aot import to_hlo_text, _tuplify

    table = model.export_table()
    assert len(table) >= 10
    # Lower a representative subset (full set is exercised by `make
    # artifacts`); assert the HLO text has an ENTRY computation.
    for name in ("gather_f32", "scatter_add_f32", "range_fuse_u32"):
        fn, specs = table[name]
        text = to_hlo_text(jax.jit(_tuplify(fn)).lower(*specs))
        assert "ENTRY" in text, f"{name} HLO missing entry computation"


def test_manifest_constants_consistent():
    assert model.DATA_N % model.TILE == 0
    assert model.RANGE_CAP >= model.TILE
