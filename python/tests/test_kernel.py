"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes, dtypes, and value distributions — the core
correctness signal for the kernels whose HLO the Rust runtime executes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import alu, gather, ref, rmw

SETTINGS = dict(max_examples=25, deadline=None)


def np_f32(draw_shape, elements=st.floats(-1e3, 1e3, width=32)):
    return st.lists(elements, min_size=draw_shape, max_size=draw_shape).map(
        lambda xs: np.array(xs, dtype=np.float32)
    )


sizes = st.sampled_from([1, 7, 64, 512, 1024, 1536])


@given(n=sizes, data=st.data())
@settings(**SETTINGS)
def test_gather_matches_ref(n, data):
    d = data.draw(np_f32(256))
    idx = np.array(
        data.draw(st.lists(st.integers(0, 255), min_size=n, max_size=n)),
        dtype=np.int32,
    )
    got = gather.gather(jnp.asarray(d), jnp.asarray(idx))
    want = ref.gather(jnp.asarray(d), jnp.asarray(idx))
    np.testing.assert_allclose(got, want)


@given(n=sizes, data=st.data())
@settings(**SETTINGS)
def test_gather_cond_matches_ref(n, data):
    d = data.draw(np_f32(256))
    idx = np.array(
        data.draw(st.lists(st.integers(0, 255), min_size=n, max_size=n)),
        dtype=np.int32,
    )
    cond = np.array(
        data.draw(st.lists(st.integers(0, 1), min_size=n, max_size=n)),
        dtype=np.int32,
    )
    got = gather.gather_cond(jnp.asarray(d), jnp.asarray(idx), jnp.asarray(cond))
    want = ref.gather_cond(jnp.asarray(d), jnp.asarray(idx), jnp.asarray(cond))
    np.testing.assert_allclose(got, want)


@pytest.mark.parametrize("op", ["add", "sub", "mul", "min", "max", "lt", "ge", "eq"])
@given(n=sizes, data=st.data())
@settings(max_examples=8, deadline=None)
def test_aluv_f32_ops(op, n, data):
    a = np.array(
        data.draw(st.lists(st.floats(-100, 100, width=32), min_size=n, max_size=n)),
        dtype=np.float32,
    )
    b = np.array(
        data.draw(st.lists(st.floats(-100, 100, width=32), min_size=n, max_size=n)),
        dtype=np.float32,
    )
    got = alu.aluv(jnp.asarray(a), jnp.asarray(b), op=op)
    want = ref.alu(jnp.asarray(a), jnp.asarray(b), op)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("op", ["and", "or", "xor", "shr", "shl"])
@given(n=sizes, data=st.data())
@settings(max_examples=8, deadline=None)
def test_aluv_u32_bitwise(op, n, data):
    a = np.array(
        data.draw(st.lists(st.integers(0, 2**31), min_size=n, max_size=n)),
        dtype=np.uint32,
    )
    shift_elems = st.integers(0, 31) if op in ("shr", "shl") else st.integers(0, 2**31)
    b = np.array(
        data.draw(st.lists(shift_elems, min_size=n, max_size=n)),
        dtype=np.uint32,
    )
    got = alu.aluv(jnp.asarray(a), jnp.asarray(b), op=op)
    want = ref.alu(jnp.asarray(a), jnp.asarray(b), op)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(n=sizes, s=st.floats(-50, 50, width=32, allow_subnormal=False), data=st.data())
@settings(**SETTINGS)
def test_alus_scalar(n, s, data):
    a = np.array(
        data.draw(st.lists(st.floats(-100, 100, width=32), min_size=n, max_size=n)),
        dtype=np.float32,
    )
    got = alu.alus(jnp.asarray(a), jnp.float32(s), op="mul")
    # atol tolerates XLA flush-to-zero on subnormal products.
    np.testing.assert_allclose(got, a * np.float32(s), rtol=1e-6, atol=1e-30)


@pytest.mark.parametrize("op", ["add", "min", "max"])
@given(n=sizes, data=st.data())
@settings(max_examples=8, deadline=None)
def test_rmw_combine(op, n, data):
    old = np.array(
        data.draw(st.lists(st.floats(-100, 100, width=32), min_size=n, max_size=n)),
        dtype=np.float32,
    )
    val = np.array(
        data.draw(st.lists(st.floats(-100, 100, width=32), min_size=n, max_size=n)),
        dtype=np.float32,
    )
    got = rmw.rmw_combine(jnp.asarray(old), jnp.asarray(val), op=op)
    want = ref.rmw_combine(jnp.asarray(old), jnp.asarray(val), op)
    np.testing.assert_allclose(got, want)


def test_rmw_rejects_non_commutative():
    a = jnp.zeros(8, jnp.float32)
    with pytest.raises(ValueError):
        rmw.rmw_combine(a, a, op="sub")


def test_hash_index_chain():
    keys = jnp.asarray((np.arange(1024, dtype=np.uint64) * 2654435761 % (2**32)).astype(np.uint32))
    got = alu.hash_index(keys, jnp.uint32(0xFFF0), jnp.uint32(4))
    want = ref.hash_index(keys, jnp.uint32(0xFFF0), jnp.uint32(4))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gather_large_tile_block_boundary():
    # Exactly BLOCK-multiple and non-multiple sizes.
    d = jnp.arange(4096, dtype=jnp.float32)
    for n in (512, 1024, 513, 4095):
        idx = jnp.asarray(np.random.default_rng(0).integers(0, 4096, n), dtype=jnp.int32)
        np.testing.assert_allclose(gather.gather(d, idx), d[idx])
